//! ASCII table rendering — every paper table (T1..T10) is emitted through
//! this so `sakuraone report` output lines up with EXPERIMENTS.md.

/// A simple left-aligned table with a header row.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row<S: ToString>(&mut self, cells: &[S]) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row arity must match headers"
        );
        self.rows.push(cells.iter().map(|c| c.to_string()).collect());
        self
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                w[i] = w[i].max(cell.len());
            }
        }
        w
    }

    pub fn render(&self) -> String {
        let w = self.widths();
        let sep: String = {
            let mut s = String::from("+");
            for wi in &w {
                s.push_str(&"-".repeat(wi + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!(" {:<width$} |", c, width = w[i]));
            }
            s
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("{}\n", self.title));
        }
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }
}

/// Two-column "Item | Value" table, the paper's summary-table style.
pub fn kv_table(title: &str, pairs: &[(&str, String)]) -> String {
    let mut t = Table::new(title, &["Item", "Value"]);
    for (k, v) in pairs {
        t.row(&[k.to_string(), v.clone()]);
    }
    t.render()
}

/// Three-way comparison row used by EXPERIMENTS.md: paper vs measured.
pub fn compare_table(
    title: &str,
    rows: &[(&str, String, String)],
) -> String {
    let mut t = Table::new(title, &["Item", "Paper", "Measured"]);
    for (k, p, m) in rows {
        t.row(&[k.to_string(), p.clone(), m.clone()]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("T", &["a", "bb"]);
        t.row(&["xxx", "y"]);
        let s = t.render();
        assert!(s.contains("| xxx | y  |"), "{s}");
        assert!(s.contains("| a   | bb |"), "{s}");
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(&["only-one"]);
    }

    #[test]
    fn kv_has_both_columns() {
        let s = kv_table("HPL", &[("FLOPS", "33.95 PFLOP/s".into())]);
        assert!(s.contains("FLOPS"));
        assert!(s.contains("33.95"));
    }

    #[test]
    fn empty_table_renders_header_only() {
        let t = Table::new("", &["h"]);
        let s = t.render();
        assert!(s.contains("| h |"));
    }
}
