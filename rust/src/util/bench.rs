//! In-repo micro-benchmark harness (criterion is not in the vendored crate
//! set). `cargo bench` targets use this through `harness = false`, and the
//! `sakuraone bench` subcommand drives the same harness to emit the
//! committed `BENCH_*.json` perf trajectory (docs/bench.md).
//!
//! Methodology: warmup iterations, then timed batches until both a minimum
//! wall budget and a minimum iteration count are met; reports mean, p50,
//! p99 and derived throughput. Deterministic ordering, no threads.

use std::time::{Duration, Instant};

use super::stats;

#[derive(Debug, Clone)]
pub struct BenchConfig {
    pub warmup_iters: u32,
    pub min_iters: u32,
    pub min_duration: Duration,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self {
            warmup_iters: 3,
            min_iters: 10,
            min_duration: Duration::from_millis(300),
        }
    }
}

impl BenchConfig {
    /// The CI smoke budget: enough samples for a stable ballpark, small
    /// enough that the whole suite runs in seconds (`bench --quick`).
    pub fn quick() -> Self {
        Self {
            warmup_iters: 1,
            min_iters: 3,
            min_duration: Duration::from_millis(40),
        }
    }
}

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u32,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    pub min_ns: f64,
    /// Machine-independent work counter returned by the benched closure
    /// (e.g. `SimReport.rounds`): the deterministic quantity the manifest
    /// gate compares across machines, unlike the timings (docs/bench.md).
    /// 0 when the case reports no counter.
    pub counter: u64,
}

impl BenchResult {
    pub fn mean_secs(&self) -> f64 {
        self.mean_ns * 1e-9
    }

    pub fn report_line(&self) -> String {
        format!(
            "{:<44} {:>12} {:>12} {:>12}   ({} iters)",
            self.name,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p99_ns),
            self.iters
        )
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.3} s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.3} ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.3} us", ns / 1e3)
    } else {
        format!("{ns:.0} ns")
    }
}

pub struct Bencher {
    config: BenchConfig,
    results: Vec<BenchResult>,
    quiet: bool,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new()
    }
}

impl Bencher {
    pub fn new() -> Self {
        Self { config: BenchConfig::default(), results: Vec::new(), quiet: false }
    }

    pub fn with_config(config: BenchConfig) -> Self {
        Self { config, results: Vec::new(), quiet: false }
    }

    /// Suppress per-case report lines (the `bench --json` path prints the
    /// manifest on stdout, so the harness must stay silent there).
    pub fn set_quiet(&mut self, quiet: bool) {
        self.quiet = quiet;
    }

    /// Time `f`, preventing the closure's result from being optimised out.
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) {
        self.run_case(name, 0, || {
            std::hint::black_box(f());
        });
    }

    /// Time `f` and record the work counter it returns (the counter of the
    /// last timed iteration — deterministic cases return the same value
    /// every iteration, which is what the manifest gate relies on).
    pub fn bench_counted<F: FnMut() -> u64>(&mut self, name: &str, mut f: F) {
        let mut counter = 0u64;
        self.run_case(name, 0, || {
            counter = std::hint::black_box(f());
        });
        if let Some(last) = self.results.last_mut() {
            last.counter = counter;
        }
    }

    fn run_case(&mut self, name: &str, counter: u64, mut iter: impl FnMut()) {
        for _ in 0..self.config.warmup_iters {
            iter();
        }
        let mut samples_ns: Vec<f64> = Vec::new();
        let start = Instant::now();
        while samples_ns.len() < self.config.min_iters as usize
            || start.elapsed() < self.config.min_duration
        {
            let t0 = Instant::now();
            iter();
            samples_ns.push(t0.elapsed().as_nanos() as f64);
            if samples_ns.len() > 100_000 {
                break;
            }
        }
        let res = BenchResult {
            name: name.to_string(),
            iters: samples_ns.len() as u32,
            mean_ns: stats::mean(&samples_ns),
            p50_ns: stats::percentile(&samples_ns, 50.0),
            p99_ns: stats::percentile(&samples_ns, 99.0),
            min_ns: stats::min(&samples_ns),
            counter,
        };
        if !self.quiet {
            println!("{}", res.report_line());
        }
        self.results.push(res);
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    pub fn header(title: &str) {
        println!("\n== {title} ==");
        println!(
            "{:<44} {:>12} {:>12} {:>12}",
            "benchmark", "mean", "p50", "p99"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut b = Bencher::with_config(BenchConfig {
            warmup_iters: 1,
            min_iters: 5,
            min_duration: Duration::from_millis(1),
        });
        b.bench("noop-ish", || {
            let mut s = 0u64;
            for i in 0..100u64 {
                s = s.wrapping_add(i * i);
            }
            s
        });
        let r = &b.results()[0];
        assert!(r.iters >= 5);
        assert!(r.mean_ns > 0.0);
        assert!(r.p99_ns >= r.p50_ns);
        assert!(r.min_ns <= r.mean_ns);
        assert_eq!(r.counter, 0);
    }

    #[test]
    fn counted_bench_records_the_counter() {
        let mut b = Bencher::with_config(BenchConfig::quick());
        b.set_quiet(true);
        b.bench_counted("counted", || 42);
        let r = &b.results()[0];
        assert_eq!(r.counter, 42);
        assert!(r.iters >= 3);
    }
}
