//! Rail/pod-aware node placement.
//!
//! On a rail-optimized fabric, a job whose nodes sit in one pod keeps all
//! per-rail traffic on single leaf switches; spanning pods pushes every
//! rail through the spine layer. The placer therefore prefers (a) a single
//! pod, (b) contiguous node ranges (which also align with how HPL grids
//! map ranks).

use crate::config::ClusterConfig;
use crate::topology::pod_of;

#[derive(Debug, Clone, PartialEq)]
pub struct Placement {
    pub nodes: Vec<usize>,
    /// Number of pods the allocation spans (1 is ideal).
    pub pods_spanned: usize,
}

/// Choose `want` nodes from `free` (sorted ascending).
/// Strategy: try to fit entirely inside one pod (pick the pod with the
/// most free nodes); otherwise take contiguous-ish nodes across pods.
pub fn place(cfg: &ClusterConfig, free: &[usize], want: usize) -> Option<Placement> {
    if want == 0 || free.len() < want {
        return None;
    }
    let pods = cfg.network.pods;
    let mut per_pod: Vec<Vec<usize>> = vec![Vec::new(); pods];
    for &n in free {
        per_pod[pod_of(cfg, n)].push(n);
    }
    // single-pod fit: choose the pod with the fewest free nodes that still
    // fits (best-fit, keeps big pods open for big jobs)
    let mut best: Option<usize> = None;
    for (p, nodes) in per_pod.iter().enumerate() {
        if nodes.len() >= want {
            let better = match best {
                None => true,
                Some(b) => per_pod[b].len() > nodes.len(),
            };
            if better {
                best = Some(p);
            }
        }
    }
    if let Some(p) = best {
        return Some(Placement {
            nodes: per_pod[p][..want].to_vec(),
            pods_spanned: 1,
        });
    }
    // spill across pods, preferring to exhaust one pod before the next
    per_pod.sort_by_key(|v| std::cmp::Reverse(v.len()));
    let mut chosen = Vec::with_capacity(want);
    let mut spanned = 0;
    for nodes in per_pod {
        if nodes.is_empty() {
            continue;
        }
        if chosen.len() >= want {
            break;
        }
        spanned += 1;
        for n in nodes {
            if chosen.len() >= want {
                break;
            }
            chosen.push(n);
        }
    }
    chosen.sort_unstable();
    Some(Placement { nodes: chosen, pods_spanned: spanned })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;

    fn cfg() -> ClusterConfig {
        ClusterConfig::default()
    }

    #[test]
    fn small_job_lands_in_one_pod() {
        let free: Vec<usize> = (0..100).collect();
        let p = place(&cfg(), &free, 10).unwrap();
        assert_eq!(p.pods_spanned, 1);
        assert_eq!(p.nodes.len(), 10);
    }

    #[test]
    fn big_job_spans_pods() {
        let free: Vec<usize> = (0..100).collect();
        let p = place(&cfg(), &free, 98).unwrap();
        assert_eq!(p.pods_spanned, 2);
        assert_eq!(p.nodes.len(), 98);
    }

    #[test]
    fn best_fit_prefers_smaller_pod_remainder() {
        // pod0 has 30 free, pod1 has 12 free; a 10-node job should take
        // pod1 (best fit), leaving pod0 intact for larger jobs.
        let c = cfg();
        let mut free: Vec<usize> = (0..30).collect();
        free.extend(50..62);
        let p = place(&c, &free, 10).unwrap();
        assert_eq!(p.pods_spanned, 1);
        assert!(p.nodes.iter().all(|&n| n >= 50));
    }

    #[test]
    fn insufficient_nodes_is_none() {
        let free: Vec<usize> = (0..5).collect();
        assert!(place(&cfg(), &free, 6).is_none());
    }

    #[test]
    fn zero_request_is_none() {
        let free: Vec<usize> = (0..5).collect();
        assert!(place(&cfg(), &free, 0).is_none());
    }

    #[test]
    fn exact_fit_consumes_the_whole_free_list() {
        // want == free.len(): every node is taken, no duplicates
        let free: Vec<usize> = (10..22).collect();
        let p = place(&cfg(), &free, 12).unwrap();
        assert_eq!(p.nodes, free);
        assert_eq!(p.pods_spanned, 1);

        // exact fit across the pod boundary (node 50) spans both pods
        let free: Vec<usize> = (48..52).collect();
        let p = place(&cfg(), &free, 4).unwrap();
        assert_eq!(p.nodes, free);
        assert_eq!(p.pods_spanned, 2);
    }

    #[test]
    fn fragmented_free_list_places_from_the_scraps() {
        // non-contiguous scraps on both sides of the pod boundary; a job
        // that fits in one pod's fragments must stay inside that pod
        let free = vec![3, 7, 19, 31, 44, 51, 58, 72, 95];
        let p = place(&cfg(), &free, 4).unwrap();
        assert_eq!(p.nodes.len(), 4);
        assert_eq!(p.pods_spanned, 1);
        assert!(p.nodes.iter().all(|n| free.contains(n)));
        let mut dedup = p.nodes.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), 4, "duplicate nodes in {:?}", p.nodes);

        // forcing a spill: 6 nodes only exist across both pods
        let p = place(&cfg(), &free, 6).unwrap();
        assert_eq!(p.nodes.len(), 6);
        assert_eq!(p.pods_spanned, 2);
        // spill output is sorted so downstream free-list math stays stable
        assert!(p.nodes.windows(2).all(|w| w[0] < w[1]), "{:?}", p.nodes);
    }

    #[test]
    fn want_beyond_capacity_is_none_even_when_fragmented() {
        let free = vec![3, 51, 95];
        assert!(place(&cfg(), &free, 4).is_none());
        assert!(place(&cfg(), &[], 1).is_none());
        // boundary: one more than the free count
        let free: Vec<usize> = (0..99).collect();
        assert!(place(&cfg(), &free, 100).is_none());
    }
}
