//! Job model for the Slurm-like workload manager (paper §3, Table 6:
//! slurm 22.05.9).

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    Pending,
    Running,
    Completed,
    Cancelled,
}

#[derive(Debug, Clone)]
pub struct Job {
    pub id: u64,
    pub name: String,
    /// Whole nodes requested (SAKURAONE allocates by node: 8 GPUs each).
    pub nodes: usize,
    /// Requested wall limit (s).
    pub time_limit: f64,
    /// Actual runtime (s) — known to the simulator, not to the scheduler.
    pub runtime: f64,
    pub priority: i64,
    pub submit_time: f64,
    pub state: JobState,
}

impl Job {
    pub fn new(id: u64, name: &str, nodes: usize, time_limit: f64, runtime: f64) -> Self {
        Self {
            id,
            name: name.to_string(),
            nodes,
            time_limit,
            runtime: runtime.min(time_limit),
            priority: 0,
            submit_time: 0.0,
            state: JobState::Pending,
        }
    }

    pub fn with_priority(mut self, p: i64) -> Self {
        self.priority = p;
        self
    }

    pub fn with_submit_time(mut self, t: f64) -> Self {
        self.submit_time = t;
        self
    }
}

/// A granted allocation.
#[derive(Debug, Clone)]
pub struct Allocation {
    pub job_id: u64,
    pub nodes: Vec<usize>,
    pub start: f64,
    pub end: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runtime_clamped_to_limit() {
        let j = Job::new(1, "train", 4, 100.0, 500.0);
        assert_eq!(j.runtime, 100.0);
    }

    #[test]
    fn builder_chain() {
        let j = Job::new(2, "hpl", 98, 3600.0, 400.0)
            .with_priority(10)
            .with_submit_time(5.0);
        assert_eq!(j.priority, 10);
        assert_eq!(j.submit_time, 5.0);
        assert_eq!(j.state, JobState::Pending);
    }
}
