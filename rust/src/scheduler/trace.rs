//! Workload traces: a versioned canonical JSON trace format (schema
//! [`TRACE_SCHEMA_VERSION`]), a seeded synthesizer, and replay through
//! [`SlurmSim`] under a scheduler-policy sweep.
//!
//! The synthesizer is calibrated to the workload dynamics the follow-up
//! paper reports for SAKURAONE's single-tenant LLM development
//! environment (arxiv 2604.13600): a base of long training jobs under
//! diurnal interactive bursts, with parameterized churn (cancelled /
//! failed / timed-out fractions). The `multi-tenant-week` preset is the
//! contrasting ABCI 3.0-style operating point (arxiv 2411.09134): many
//! accounts, flatter diurnal swing, smaller and shorter jobs.
//!
//! Codec contract (shared with the scenario and cluster codecs via
//! `util::codec`): `to_json` emits every field with sorted keys —
//! deterministic bytes; `from_json` accepts sparse job objects with
//! documented defaults and rejects unknown fields and version
//! mismatches; the round trip is exact and re-emission byte-identical.
//! Synthesis is a pure function of `(SynthConfig, seed)` on the seeded
//! RNG substrate, so traces are byte-reproducible; replay is free of
//! randomness, so `(trace, cluster, policy)` fixes the report.

use std::collections::{BTreeMap, BTreeSet};

use crate::config::ClusterConfig;
use crate::util::codec::{
    check_keys, check_schema, f64_or, int_or, jint, jnum, jstr, name_or, obj,
    str_or, usize_or,
};
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::stats;

use super::fairshare::FairShare;
use super::job::Job;
use super::slurm::SlurmSim;

/// Version of the trace wire encoding; every trace document carries it
/// as `"schema"`. Bump when the job field set changes incompatibly.
pub const TRACE_SCHEMA_VERSION: u64 = 1;

/// How a traced job ended on the real (or synthetic) cluster.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Outcome {
    Completed,
    Failed,
    Cancelled,
    Timeout,
}

impl Outcome {
    pub const ALL: [Outcome; 4] = [
        Outcome::Completed,
        Outcome::Failed,
        Outcome::Cancelled,
        Outcome::Timeout,
    ];

    /// Wire name (`"outcome"` in trace JSON).
    pub fn name(self) -> &'static str {
        match self {
            Outcome::Completed => "completed",
            Outcome::Failed => "failed",
            Outcome::Cancelled => "cancelled",
            Outcome::Timeout => "timeout",
        }
    }

    pub fn parse(s: &str) -> Result<Outcome, String> {
        Outcome::ALL
            .into_iter()
            .find(|o| o.name() == s)
            .ok_or_else(|| {
                let known =
                    Outcome::ALL.map(Outcome::name).join(", ");
                format!("unknown job outcome {s:?} (known: {known})")
            })
    }
}

/// One job in a workload trace. `requested_s` is what the user asked
/// Slurm for (the wall limit backfill reasons about); `runtime_s` is
/// what actually happened.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceJob {
    pub id: u64,
    pub account: String,
    /// Submission time, seconds from trace start.
    pub submit_s: f64,
    /// Whole nodes (SAKURAONE allocates by node).
    pub nodes: usize,
    pub gpus_per_node: usize,
    /// Requested wall limit (s).
    pub requested_s: f64,
    /// Actual runtime (s).
    pub runtime_s: f64,
    pub outcome: Outcome,
}

const JOB_KEYS: &[&str] = &[
    "account", "gpus_per_node", "id", "nodes", "outcome", "requested_s",
    "runtime_s", "submit_s",
];

impl TraceJob {
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("account".into(), jstr(&self.account));
        m.insert("gpus_per_node".into(), jint(self.gpus_per_node as u64));
        m.insert("id".into(), jint(self.id));
        m.insert("nodes".into(), jint(self.nodes as u64));
        m.insert("outcome".into(), jstr(self.outcome.name()));
        m.insert("requested_s".into(), jnum(self.requested_s));
        m.insert("runtime_s".into(), jnum(self.runtime_s));
        m.insert("submit_s".into(), jnum(self.submit_s));
        Json::Obj(m)
    }

    /// Decode one job object; sparse fields take defaults (`id` defaults
    /// to the job's index in the `jobs` array).
    fn from_json(j: &Json, default_id: u64, at: &str) -> Result<TraceJob, String> {
        let m = obj(j, at)?;
        check_keys(m, JOB_KEYS, at)?;
        let nodes = usize_or(m, "nodes", 1, at)?;
        if nodes == 0 {
            return Err(format!("{at}.nodes: must be at least 1"));
        }
        for key in ["submit_s", "requested_s", "runtime_s"] {
            if f64_or(m, key, 0.0, at)? < 0.0 {
                return Err(format!("{at}.{key}: must be non-negative"));
            }
        }
        Ok(TraceJob {
            id: int_or(m, "id", default_id, at)?,
            account: str_or(m, "account", "acct-00", at)?,
            submit_s: f64_or(m, "submit_s", 0.0, at)?,
            nodes,
            gpus_per_node: usize_or(m, "gpus_per_node", 8, at)?,
            requested_s: f64_or(m, "requested_s", 3600.0, at)?,
            runtime_s: f64_or(m, "runtime_s", 1800.0, at)?,
            outcome: name_or(
                m,
                "outcome",
                Outcome::Completed,
                at,
                "job outcome",
                Outcome::parse,
            )?,
        })
    }
}

/// A workload trace: a named list of jobs (canonical order: as listed;
/// replay sorts by submit time).
#[derive(Debug, Clone, PartialEq)]
pub struct Trace {
    pub name: String,
    pub jobs: Vec<TraceJob>,
}

impl Trace {
    /// Canonical encoding: `{"jobs": [...], "name": ..., "schema": 1}`
    /// (keys sorted by the `BTreeMap`), every job field present.
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("schema".into(), jint(TRACE_SCHEMA_VERSION));
        m.insert("name".into(), jstr(&self.name));
        m.insert(
            "jobs".into(),
            Json::Arr(self.jobs.iter().map(TraceJob::to_json).collect()),
        );
        Json::Obj(m)
    }

    /// Decode a trace document. The `"schema"` field is required and
    /// must match [`TRACE_SCHEMA_VERSION`]; job ids must be unique.
    pub fn from_json(j: &Json) -> Result<Trace, String> {
        let m = obj(j, "trace")?;
        check_keys(m, &["jobs", "name", "schema"], "trace")?;
        check_schema(m, TRACE_SCHEMA_VERSION, "trace")?;
        let name = str_or(m, "name", "unnamed", "trace")?;
        let mut jobs = Vec::new();
        if let Some(v) = m.get("jobs") {
            let arr = v.as_arr().ok_or_else(|| {
                "trace.jobs: expected an array of job objects".to_string()
            })?;
            let mut seen = BTreeSet::new();
            for (i, jj) in arr.iter().enumerate() {
                let at = format!("trace.jobs[{i}]");
                let job = TraceJob::from_json(jj, i as u64, &at)?;
                if !seen.insert(job.id) {
                    return Err(format!("{at}.id: duplicate job id {}", job.id));
                }
                jobs.push(job);
            }
        }
        Ok(Trace { name, jobs })
    }

    pub fn parse(text: &str) -> Result<Trace, String> {
        Trace::from_json(&Json::parse(text)?)
    }
}

// ---------------------------------------------------------------------------
// Synthesis

/// Calibration knobs for the synthetic generator. The defaults
/// ([`SynthConfig::dev_cluster_week`]) follow the follow-up paper's
/// single-tenant dev-cluster dynamics; [`SynthConfig::multi_tenant_week`]
/// is the ABCI 3.0-style contrast.
#[derive(Debug, Clone, PartialEq)]
pub struct SynthConfig {
    /// Trace name (also the synthesized trace's `name`).
    pub name: String,
    pub duration_days: f64,
    /// Distinct accounts jobs are drawn across.
    pub accounts: usize,
    pub gpus_per_node: usize,
    /// Long training jobs — the base load.
    pub training_jobs: usize,
    pub training_nodes_max: usize,
    pub training_runtime_median_s: f64,
    /// Lognormal shape parameter for training runtimes.
    pub training_runtime_sigma: f64,
    /// Mean interactive arrivals per hour (0 disables the burst stream).
    pub interactive_per_hour: f64,
    /// Diurnal swing of the interactive rate, 0 (flat) to 1 (full swing).
    pub diurnal_amplitude: f64,
    /// Local hour of peak interactive activity.
    pub peak_hour: f64,
    pub interactive_nodes_max: usize,
    pub interactive_runtime_median_s: f64,
    pub interactive_runtime_sigma: f64,
    /// Churn: fractions of jobs (re)classified as cancelled / failed /
    /// timed out, in that precedence order.
    pub cancelled_fraction: f64,
    pub failed_fraction: f64,
    pub timeout_fraction: f64,
}

const SYNTH_KEYS: &[&str] = &[
    "accounts",
    "cancelled_fraction",
    "diurnal_amplitude",
    "duration_days",
    "failed_fraction",
    "gpus_per_node",
    "interactive_nodes_max",
    "interactive_per_hour",
    "interactive_runtime_median_s",
    "interactive_runtime_sigma",
    "name",
    "peak_hour",
    "timeout_fraction",
    "training_jobs",
    "training_nodes_max",
    "training_runtime_median_s",
    "training_runtime_sigma",
];

impl SynthConfig {
    /// One week on a single-tenant LLM dev cluster (arxiv 2604.13600):
    /// a dozen long training jobs, a strong afternoon-peaked interactive
    /// diurnal, moderate churn.
    pub fn dev_cluster_week() -> Self {
        Self {
            name: "dev-week".into(),
            duration_days: 7.0,
            accounts: 6,
            gpus_per_node: 8,
            training_jobs: 12,
            training_nodes_max: 48,
            training_runtime_median_s: 43_200.0,
            training_runtime_sigma: 0.6,
            interactive_per_hour: 6.0,
            diurnal_amplitude: 0.8,
            peak_hour: 14.0,
            interactive_nodes_max: 4,
            interactive_runtime_median_s: 1800.0,
            interactive_runtime_sigma: 0.9,
            cancelled_fraction: 0.10,
            failed_fraction: 0.06,
            timeout_fraction: 0.04,
        }
    }

    /// One week at a shared multi-tenant operating point (ABCI 3.0
    /// contrast, arxiv 2411.09134): many accounts, flatter diurnal,
    /// higher arrival rate of smaller and shorter jobs.
    pub fn multi_tenant_week() -> Self {
        Self {
            name: "multi-tenant-week".into(),
            duration_days: 7.0,
            accounts: 24,
            gpus_per_node: 8,
            training_jobs: 30,
            training_nodes_max: 16,
            training_runtime_median_s: 14_400.0,
            training_runtime_sigma: 0.8,
            interactive_per_hour: 30.0,
            diurnal_amplitude: 0.3,
            peak_hour: 13.0,
            interactive_nodes_max: 2,
            interactive_runtime_median_s: 900.0,
            interactive_runtime_sigma: 1.0,
            cancelled_fraction: 0.12,
            failed_fraction: 0.08,
            timeout_fraction: 0.05,
        }
    }

    /// Preset lookup by wire name (`sakuraone trace synth --preset`).
    pub fn preset(name: &str) -> Result<SynthConfig, String> {
        match name {
            "dev-week" => Ok(Self::dev_cluster_week()),
            "multi-tenant-week" => Ok(Self::multi_tenant_week()),
            other => Err(format!(
                "unknown synth preset {other:?} (known: dev-week, multi-tenant-week)"
            )),
        }
    }

    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("accounts".into(), jint(self.accounts as u64));
        m.insert("cancelled_fraction".into(), jnum(self.cancelled_fraction));
        m.insert("diurnal_amplitude".into(), jnum(self.diurnal_amplitude));
        m.insert("duration_days".into(), jnum(self.duration_days));
        m.insert("failed_fraction".into(), jnum(self.failed_fraction));
        m.insert("gpus_per_node".into(), jint(self.gpus_per_node as u64));
        m.insert(
            "interactive_nodes_max".into(),
            jint(self.interactive_nodes_max as u64),
        );
        m.insert("interactive_per_hour".into(), jnum(self.interactive_per_hour));
        m.insert(
            "interactive_runtime_median_s".into(),
            jnum(self.interactive_runtime_median_s),
        );
        m.insert(
            "interactive_runtime_sigma".into(),
            jnum(self.interactive_runtime_sigma),
        );
        m.insert("name".into(), jstr(&self.name));
        m.insert("peak_hour".into(), jnum(self.peak_hour));
        m.insert("timeout_fraction".into(), jnum(self.timeout_fraction));
        m.insert("training_jobs".into(), jint(self.training_jobs as u64));
        m.insert(
            "training_nodes_max".into(),
            jint(self.training_nodes_max as u64),
        );
        m.insert(
            "training_runtime_median_s".into(),
            jnum(self.training_runtime_median_s),
        );
        m.insert(
            "training_runtime_sigma".into(),
            jnum(self.training_runtime_sigma),
        );
        Json::Obj(m)
    }

    /// Sparse decode against `base` (unknown fields rejected).
    pub fn from_json(j: &Json, base: SynthConfig, at: &str) -> Result<SynthConfig, String> {
        let m = obj(j, at)?;
        check_keys(m, SYNTH_KEYS, at)?;
        Ok(SynthConfig {
            name: str_or(m, "name", &base.name, at)?,
            duration_days: f64_or(m, "duration_days", base.duration_days, at)?,
            accounts: usize_or(m, "accounts", base.accounts, at)?,
            gpus_per_node: usize_or(m, "gpus_per_node", base.gpus_per_node, at)?,
            training_jobs: usize_or(m, "training_jobs", base.training_jobs, at)?,
            training_nodes_max: usize_or(
                m,
                "training_nodes_max",
                base.training_nodes_max,
                at,
            )?,
            training_runtime_median_s: f64_or(
                m,
                "training_runtime_median_s",
                base.training_runtime_median_s,
                at,
            )?,
            training_runtime_sigma: f64_or(
                m,
                "training_runtime_sigma",
                base.training_runtime_sigma,
                at,
            )?,
            interactive_per_hour: f64_or(
                m,
                "interactive_per_hour",
                base.interactive_per_hour,
                at,
            )?,
            diurnal_amplitude: f64_or(
                m,
                "diurnal_amplitude",
                base.diurnal_amplitude,
                at,
            )?,
            peak_hour: f64_or(m, "peak_hour", base.peak_hour, at)?,
            interactive_nodes_max: usize_or(
                m,
                "interactive_nodes_max",
                base.interactive_nodes_max,
                at,
            )?,
            interactive_runtime_median_s: f64_or(
                m,
                "interactive_runtime_median_s",
                base.interactive_runtime_median_s,
                at,
            )?,
            interactive_runtime_sigma: f64_or(
                m,
                "interactive_runtime_sigma",
                base.interactive_runtime_sigma,
                at,
            )?,
            cancelled_fraction: f64_or(
                m,
                "cancelled_fraction",
                base.cancelled_fraction,
                at,
            )?,
            failed_fraction: f64_or(m, "failed_fraction", base.failed_fraction, at)?,
            timeout_fraction: f64_or(m, "timeout_fraction", base.timeout_fraction, at)?,
        })
    }
}

/// Synthesize a trace: a pure function of `(cfg, seed)`.
///
/// Three forked RNG streams keep the generator stable under knob
/// changes: stream 1 draws the training base, stream 2 the interactive
/// arrivals (a non-homogeneous Poisson process via thinning against the
/// diurnal rate), stream 3 the churn reclassification. Jobs are sorted
/// by submit time and numbered 0..n.
pub fn synthesize(cfg: &SynthConfig, seed: u64) -> Trace {
    let mut root = Rng::new(seed);
    let duration_s = cfg.duration_days * 86_400.0;
    let mut jobs: Vec<TraceJob> = Vec::new();

    let mut tr = root.fork(1);
    for _ in 0..cfg.training_jobs {
        let nodes = 1 + tr.below(cfg.training_nodes_max.max(1) as u64) as usize;
        let runtime = tr.lognormal(cfg.training_runtime_median_s, cfg.training_runtime_sigma);
        let submit = tr.range(0.0, duration_s.max(1.0));
        let account = format!("acct-{:02}", tr.below(cfg.accounts.max(1) as u64));
        // users pad training wall limits modestly (1.25-2x actual)
        let margin = 1.25 + 0.75 * tr.uniform();
        jobs.push(TraceJob {
            id: 0,
            account,
            submit_s: submit,
            nodes,
            gpus_per_node: cfg.gpus_per_node,
            requested_s: runtime * margin,
            runtime_s: runtime,
            outcome: Outcome::Completed,
        });
    }

    let mut ia = root.fork(2);
    if cfg.interactive_per_hour > 0.0 && duration_s > 0.0 {
        let base_rate = cfg.interactive_per_hour / 3600.0;
        let amp = cfg.diurnal_amplitude.clamp(0.0, 1.0);
        let max_rate = base_rate * (1.0 + amp);
        let mut t = ia.exponential(max_rate);
        while t < duration_s {
            let hour = (t / 3600.0) % 24.0;
            let phase = (hour - cfg.peak_hour) / 24.0 * std::f64::consts::TAU;
            let rate = base_rate * (1.0 + amp * phase.cos());
            if ia.uniform() * max_rate < rate {
                let nodes =
                    1 + ia.below(cfg.interactive_nodes_max.max(1) as u64) as usize;
                let runtime = ia
                    .lognormal(cfg.interactive_runtime_median_s, cfg.interactive_runtime_sigma);
                let account = format!("acct-{:02}", ia.below(cfg.accounts.max(1) as u64));
                // interactive sessions over-request heavily (2-4x)
                let margin = 2.0 + 2.0 * ia.uniform();
                jobs.push(TraceJob {
                    id: 0,
                    account,
                    submit_s: t,
                    nodes,
                    gpus_per_node: cfg.gpus_per_node,
                    requested_s: runtime * margin,
                    runtime_s: runtime,
                    outcome: Outcome::Completed,
                });
            }
            t += ia.exponential(max_rate);
        }
    }

    jobs.sort_by(|a, b| a.submit_s.total_cmp(&b.submit_s));
    let mut ch = root.fork(3);
    for (i, j) in jobs.iter_mut().enumerate() {
        j.id = i as u64;
        let u = ch.uniform();
        if u < cfg.cancelled_fraction {
            j.outcome = Outcome::Cancelled;
            j.runtime_s = (j.runtime_s * ch.uniform()).max(1.0);
        } else if u < cfg.cancelled_fraction + cfg.failed_fraction {
            j.outcome = Outcome::Failed;
            j.runtime_s = (j.runtime_s * ch.uniform()).max(1.0);
        } else if u < cfg.cancelled_fraction + cfg.failed_fraction + cfg.timeout_fraction {
            j.outcome = Outcome::Timeout;
            j.runtime_s = j.requested_s;
        }
    }
    Trace { name: cfg.name.clone(), jobs }
}

// ---------------------------------------------------------------------------
// Replay

/// Scheduler policy for a replay. `fifo` disables backfill (strict
/// priority order); `backfill` is the simulator's default conservative
/// backfill; `fairshare` adds per-account usage-decayed priority boosts
/// on top of backfill.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    Fifo,
    Backfill,
    Fairshare,
}

impl Policy {
    pub const ALL: [Policy; 3] = [Policy::Fifo, Policy::Backfill, Policy::Fairshare];

    pub fn name(self) -> &'static str {
        match self {
            Policy::Fifo => "fifo",
            Policy::Backfill => "backfill",
            Policy::Fairshare => "fairshare",
        }
    }

    pub fn parse(s: &str) -> Result<Policy, String> {
        Policy::ALL.into_iter().find(|p| p.name() == s).ok_or_else(|| {
            let known = Policy::ALL.map(Policy::name).join(", ");
            format!("unknown scheduler policy {s:?} (known: {known})")
        })
    }
}

/// What one `(trace, cluster, policy)` replay produced. Waits are
/// queue waits in seconds over all jobs (percentiles via `util::stats`).
#[derive(Debug, Clone, PartialEq)]
pub struct ReplayReport {
    pub policy: Policy,
    pub jobs: usize,
    pub completed: usize,
    pub backfilled: usize,
    pub wait_mean_s: f64,
    pub wait_p50_s: f64,
    pub wait_p90_s: f64,
    pub wait_p99_s: f64,
    pub wait_max_s: f64,
    pub utilization: f64,
    pub makespan_s: f64,
    pub single_pod_fraction: f64,
}

/// Replay a trace through the Slurm simulator under `policy`.
/// Deterministic: no randomness, submit order fixed by
/// `(submit_s, id)`. Jobs wider than the cluster are clamped to it
/// (a trace from a bigger machine still replays).
pub fn replay(trace: &Trace, cfg: &ClusterConfig, policy: Policy) -> ReplayReport {
    let mut sim = SlurmSim::new(cfg);
    if policy == Policy::Fifo {
        sim.set_backfill(false);
    }
    let mut order: Vec<&TraceJob> = trace.jobs.iter().collect();
    order.sort_by(|a, b| a.submit_s.total_cmp(&b.submit_s).then(a.id.cmp(&b.id)));
    // 24h usage half-life, the fairshare module's integration default
    let mut fs = FairShare::new(86_400.0);
    for tj in order {
        let nodes = tj.nodes.clamp(1, cfg.nodes);
        let mut job = Job::new(tj.id, &tj.account, nodes, tj.requested_s.max(1.0), tj.runtime_s)
            .with_submit_time(tj.submit_s);
        if policy == Policy::Fairshare {
            job = job.with_priority(fs.priority_boost(&tj.account, tj.submit_s));
            fs.charge(&tj.account, nodes as f64 * tj.runtime_s, tj.submit_s);
        }
        sim.submit(job);
    }
    let st = sim.run();
    let waits = sim.waits();
    let pct = |p: f64| if waits.is_empty() { 0.0 } else { stats::percentile(waits, p) };
    ReplayReport {
        policy,
        jobs: trace.jobs.len(),
        completed: st.completed,
        backfilled: st.backfilled,
        wait_mean_s: st.mean_wait,
        wait_p50_s: pct(50.0),
        wait_p90_s: pct(90.0),
        wait_p99_s: pct(99.0),
        wait_max_s: st.max_wait,
        utilization: st.utilization,
        makespan_s: st.makespan,
        single_pod_fraction: st.single_pod_fraction,
    }
}

// ---------------------------------------------------------------------------
// Summary (for `sakuraone trace stats`)

/// Shape of a trace at a glance.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceSummary {
    pub jobs: usize,
    pub accounts: usize,
    pub span_days: f64,
    pub node_hours: f64,
    pub max_nodes: usize,
    pub completed_fraction: f64,
    pub median_runtime_s: f64,
    pub p90_runtime_s: f64,
}

pub fn summarize(trace: &Trace) -> TraceSummary {
    let jobs = trace.jobs.len();
    let accounts = trace
        .jobs
        .iter()
        .map(|j| j.account.as_str())
        .collect::<BTreeSet<_>>()
        .len();
    let span_s = trace
        .jobs
        .iter()
        .map(|j| j.submit_s + j.runtime_s)
        .fold(0.0, f64::max);
    let node_hours: f64 = trace
        .jobs
        .iter()
        .map(|j| j.nodes as f64 * j.runtime_s / 3600.0)
        .sum();
    let runtimes: Vec<f64> = trace.jobs.iter().map(|j| j.runtime_s).collect();
    let completed =
        trace.jobs.iter().filter(|j| j.outcome == Outcome::Completed).count();
    TraceSummary {
        jobs,
        accounts,
        span_days: span_s / 86_400.0,
        node_hours,
        max_nodes: trace.jobs.iter().map(|j| j.nodes).max().unwrap_or(0),
        completed_fraction: if jobs > 0 { completed as f64 / jobs as f64 } else { 0.0 },
        median_runtime_s: if runtimes.is_empty() { 0.0 } else { stats::percentile(&runtimes, 50.0) },
        p90_runtime_s: if runtimes.is_empty() { 0.0 } else { stats::percentile(&runtimes, 90.0) },
    }
}

// ---------------------------------------------------------------------------
// Campaign background mix

/// A trace-fed background mix for the campaign simulator: short
/// training-shaped jobs (dev-week calibration, interactive stream off)
/// all present at t=0 with priority 1, so a restarting campaign job
/// (priority 10, submitted later) must queue behind whatever is already
/// on the machine — the requeue-wait contention `llm::campaign` models.
pub fn requeue_background_jobs(cfg: &ClusterConfig, count: usize, seed: u64) -> Vec<Job> {
    let mut synth = SynthConfig::dev_cluster_week();
    synth.name = "campaign-background".into();
    synth.training_jobs = count;
    synth.interactive_per_hour = 0.0;
    synth.training_nodes_max = (cfg.nodes / 2).max(1);
    synth.training_runtime_median_s = 900.0;
    synth.training_runtime_sigma = 0.8;
    let trace = synthesize(&synth, seed);
    trace
        .jobs
        .iter()
        .map(|tj| {
            // floor keeps every background job long enough to block the
            // restart's submit at t=60 (requeue wait stays positive)
            let rt = tj.runtime_s.max(120.0);
            Job::new(tj.id, &tj.account, tj.nodes.clamp(1, cfg.nodes), rt * 1.5, rt)
                .with_priority(1)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::codec::assert_roundtrip;

    #[test]
    fn outcome_and_policy_names_roundtrip() {
        for o in Outcome::ALL {
            assert_eq!(Outcome::parse(o.name()).unwrap(), o);
        }
        for p in Policy::ALL {
            assert_eq!(Policy::parse(p.name()).unwrap(), p);
        }
        let err = Outcome::parse("exploded").unwrap_err();
        for o in Outcome::ALL {
            assert!(err.contains(o.name()), "{err}");
        }
        let err = Policy::parse("sjf").unwrap_err();
        for p in Policy::ALL {
            assert!(err.contains(p.name()), "{err}");
        }
    }

    #[test]
    fn synthesized_traces_roundtrip_exactly() {
        for seed in [0, 1, 42] {
            let t = synthesize(&SynthConfig::dev_cluster_week(), seed);
            assert_roundtrip(&t, Trace::to_json, Trace::from_json);
        }
        let t = synthesize(&SynthConfig::multi_tenant_week(), 7);
        assert_roundtrip(&t, Trace::to_json, Trace::from_json);
    }

    #[test]
    fn synth_is_seed_deterministic() {
        let cfg = SynthConfig::dev_cluster_week();
        let a = synthesize(&cfg, 42).to_json().emit();
        let b = synthesize(&cfg, 42).to_json().emit();
        assert_eq!(a, b);
        let c = synthesize(&cfg, 43).to_json().emit();
        assert_ne!(a, c);
    }

    #[test]
    fn synth_has_base_and_burst_structure() {
        let cfg = SynthConfig::dev_cluster_week();
        let t = synthesize(&cfg, 1);
        // ids are 0..n in submit order
        for (i, j) in t.jobs.iter().enumerate() {
            assert_eq!(j.id, i as u64);
            if i > 0 {
                assert!(j.submit_s >= t.jobs[i - 1].submit_s);
            }
        }
        // ~6/h over a week plus the training base
        assert!(t.jobs.len() > 500, "only {} jobs", t.jobs.len());
        let big = t.jobs.iter().filter(|j| j.nodes > cfg.interactive_nodes_max).count();
        assert!(big >= 1 && big <= cfg.training_jobs, "big={big}");
        // churn produced every outcome class
        for o in Outcome::ALL {
            assert!(t.jobs.iter().any(|j| j.outcome == o), "no {} jobs", o.name());
        }
    }

    #[test]
    fn diurnal_peak_outdraws_trough() {
        let cfg = SynthConfig::dev_cluster_week();
        let t = synthesize(&cfg, 3);
        let near = |h: f64, center: f64| {
            let d = (h - center).abs();
            d.min(24.0 - d) <= 3.0
        };
        let trough_hour = (cfg.peak_hour + 12.0) % 24.0;
        let small: Vec<&TraceJob> =
            t.jobs.iter().filter(|j| j.nodes <= cfg.interactive_nodes_max).collect();
        let peak = small
            .iter()
            .filter(|j| near((j.submit_s / 3600.0) % 24.0, cfg.peak_hour))
            .count();
        let trough = small
            .iter()
            .filter(|j| near((j.submit_s / 3600.0) % 24.0, trough_hour))
            .count();
        assert!(
            peak > 2 * trough,
            "peak window {peak} vs trough window {trough}"
        );
    }

    #[test]
    fn sparse_trace_doc_fills_defaults() {
        let t = Trace::parse(
            r#"{"schema": 1, "jobs": [{}, {"nodes": 4, "outcome": "failed"}]}"#,
        )
        .unwrap();
        assert_eq!(t.name, "unnamed");
        assert_eq!(t.jobs.len(), 2);
        assert_eq!(t.jobs[0].id, 0);
        assert_eq!(t.jobs[0].nodes, 1);
        assert_eq!(t.jobs[0].gpus_per_node, 8);
        assert_eq!(t.jobs[0].outcome, Outcome::Completed);
        assert_eq!(t.jobs[1].id, 1);
        assert_eq!(t.jobs[1].nodes, 4);
        assert_eq!(t.jobs[1].outcome, Outcome::Failed);
    }

    #[test]
    fn bad_trace_docs_are_rejected() {
        for (doc, needle) in [
            (r#"{"jobs": []}"#, "missing \"schema\""),
            (r#"{"schema": 2, "jobs": []}"#, "version 2 is not supported"),
            (r#"{"schema": 1, "warp": 1}"#, "unknown field \"warp\""),
            (r#"{"schema": 1, "jobs": [{"warp": 1}]}"#, "unknown field \"warp\""),
            (r#"{"schema": 1, "jobs": [{"nodes": 0}]}"#, "must be at least 1"),
            (
                r#"{"schema": 1, "jobs": [{"id": 7}, {"id": 7}]}"#,
                "duplicate job id 7",
            ),
            (
                r#"{"schema": 1, "jobs": [{"submit_s": -5}]}"#,
                "must be non-negative",
            ),
            (
                r#"{"schema": 1, "jobs": [{"outcome": "exploded"}]}"#,
                "unknown job outcome",
            ),
            (r#"{"schema": 1, "jobs": 3}"#, "expected an array"),
            (r#"[]"#, "expected an object"),
        ] {
            let err = Trace::parse(doc).unwrap_err();
            assert!(err.contains(needle), "{doc}: {err}");
        }
    }

    #[test]
    fn synth_config_roundtrips_and_rejects_unknowns() {
        for cfg in [SynthConfig::dev_cluster_week(), SynthConfig::multi_tenant_week()] {
            assert_roundtrip(
                &cfg,
                SynthConfig::to_json,
                |j| SynthConfig::from_json(j, SynthConfig::dev_cluster_week(), "synth"),
            );
        }
        let err = SynthConfig::from_json(
            &Json::parse(r#"{"warp": 1}"#).unwrap(),
            SynthConfig::dev_cluster_week(),
            "synth",
        )
        .unwrap_err();
        assert!(err.contains("unknown field \"warp\""), "{err}");
    }

    #[test]
    fn replay_is_deterministic_and_fifo_never_backfills() {
        let cluster = ClusterConfig::default();
        let trace = synthesize(&SynthConfig::dev_cluster_week(), 42);
        let fifo = replay(&trace, &cluster, Policy::Fifo);
        assert_eq!(fifo, replay(&trace, &cluster, Policy::Fifo));
        assert_eq!(fifo.backfilled, 0);
        assert_eq!(fifo.completed, trace.jobs.len());
        let bf = replay(&trace, &cluster, Policy::Backfill);
        assert_eq!(bf.completed, trace.jobs.len());
        assert!(bf.wait_mean_s <= fifo.wait_mean_s, "{} vs {}", bf.wait_mean_s, fifo.wait_mean_s);
        // percentiles are ordered
        for r in [&fifo, &bf] {
            assert!(r.wait_p50_s <= r.wait_p90_s);
            assert!(r.wait_p90_s <= r.wait_p99_s);
            assert!(r.wait_p99_s <= r.wait_max_s + 1e-9);
        }
    }

    #[test]
    fn oversized_trace_jobs_are_clamped_to_the_cluster() {
        let mut cluster = ClusterConfig::default();
        cluster.apply_override("nodes", "4").unwrap();
        let t = Trace::parse(
            r#"{"schema": 1, "jobs": [{"nodes": 64, "runtime_s": 100, "requested_s": 200}]}"#,
        )
        .unwrap();
        let rep = replay(&t, &cluster, Policy::Backfill);
        assert_eq!(rep.completed, 1);
    }

    #[test]
    fn summarize_reports_the_shape() {
        let t = synthesize(&SynthConfig::dev_cluster_week(), 9);
        let s = summarize(&t);
        assert_eq!(s.jobs, t.jobs.len());
        assert!(s.accounts >= 2 && s.accounts <= 6, "accounts={}", s.accounts);
        assert!(s.span_days > 5.0 && s.span_days < 21.0, "span={}", s.span_days);
        assert!(s.completed_fraction > 0.6 && s.completed_fraction < 1.0);
        assert!(s.median_runtime_s <= s.p90_runtime_s);
        assert!(s.node_hours > 0.0);
    }

    #[test]
    fn background_jobs_feed_the_campaign_mix() {
        let cluster = ClusterConfig::default();
        let jobs = requeue_background_jobs(&cluster, 8, 42);
        assert_eq!(jobs.len(), 8);
        for (i, j) in jobs.iter().enumerate() {
            assert_eq!(j.id, i as u64);
            assert_eq!(j.submit_time, 0.0);
            assert_eq!(j.priority, 1);
            assert!(j.runtime >= 120.0, "runtime={}", j.runtime);
            assert!(j.nodes >= 1 && j.nodes <= cluster.nodes / 2);
        }
        assert!(requeue_background_jobs(&cluster, 0, 42).is_empty());
    }
}
