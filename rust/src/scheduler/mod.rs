//! Slurm-like workload manager (paper §3): jobs, rail-aware placement,
//! priority FIFO + conservative backfill.

pub mod fairshare;
pub mod job;
pub mod placement;
pub mod slurm;

pub use fairshare::{FairShare, Partition};
pub use job::{Allocation, Job, JobState};
pub use placement::{place, Placement};
pub use slurm::{SchedulerStats, SlurmSim};
