//! Slurm-like workload manager (paper §3): jobs, rail-aware placement,
//! priority FIFO + conservative backfill, and workload-trace
//! synthesis/replay (docs/traces.md).

pub mod fairshare;
pub mod job;
pub mod placement;
pub mod slurm;
pub mod trace;

pub use fairshare::{FairShare, Partition};
pub use job::{Allocation, Job, JobState};
pub use placement::{place, Placement};
pub use slurm::{SchedulerStats, SlurmSim};
pub use trace::{
    replay, summarize, synthesize, Outcome, Policy, ReplayReport, SynthConfig,
    Trace, TraceJob, TraceSummary, TRACE_SCHEMA_VERSION,
};
