//! Multi-user fair-share accounting + partitions — the operational side
//! of the paper's §3: "efficient and fair resource utilization across a
//! multi-user, multi-project environment ... job prioritization, node
//! reservation, resource limits".
//!
//! Slurm's multifactor plugin reduces, for our purposes, to: every
//! account accrues usage (node-seconds, half-life-decayed); a job's
//! effective priority = base priority + fairshare boost (under-served
//! accounts float up) + age. Partitions cap how many nodes an account
//! class may hold (the paper runs dedicated interactive front-ends next
//! to the batch pool).

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct Partition {
    pub name: String,
    /// Node ids owned by this partition.
    pub nodes: std::ops::Range<usize>,
    /// Per-account concurrent-node cap (None = no cap).
    pub max_nodes_per_account: Option<usize>,
}

impl Partition {
    pub fn batch(nodes: usize) -> Self {
        Self { name: "batch".into(), nodes: 0..nodes, max_nodes_per_account: None }
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }
}

/// Half-life-decayed usage accounting per account.
#[derive(Debug, Clone)]
pub struct FairShare {
    half_life_s: f64,
    /// account -> (decayed node-seconds, last update time)
    usage: BTreeMap<String, (f64, f64)>,
    /// account -> allocated share weight (default 1.0)
    shares: BTreeMap<String, f64>,
}

impl FairShare {
    pub fn new(half_life_s: f64) -> Self {
        assert!(half_life_s > 0.0);
        Self { half_life_s, usage: BTreeMap::new(), shares: BTreeMap::new() }
    }

    pub fn set_shares(&mut self, account: &str, weight: f64) {
        assert!(weight > 0.0);
        self.shares.insert(account.to_string(), weight);
    }

    fn decayed(&self, account: &str, now: f64) -> f64 {
        match self.usage.get(account) {
            None => 0.0,
            Some(&(u, t)) => u * 0.5f64.powf((now - t) / self.half_life_s),
        }
    }

    /// Record `node_seconds` of usage by `account` at time `now`.
    pub fn charge(&mut self, account: &str, node_seconds: f64, now: f64) {
        let u = self.decayed(account, now) + node_seconds;
        self.usage.insert(account.to_string(), (u, now));
    }

    /// Slurm-like fairshare factor in [0, 1]: 2^(-usage_norm / share_norm).
    pub fn factor(&self, account: &str, now: f64) -> f64 {
        let total_usage: f64 = self
            .usage
            .keys()
            .map(|a| self.decayed(a, now))
            .sum::<f64>()
            .max(1e-9);
        let my_usage = self.decayed(account, now) / total_usage;
        let total_shares: f64 =
            self.shares.values().sum::<f64>().max(1.0);
        let my_share =
            self.shares.get(account).copied().unwrap_or(1.0) / total_shares;
        2f64.powf(-my_usage / my_share.max(1e-9))
    }

    /// Priority boost to add to a job's base priority (scaled to ~1000s).
    pub fn priority_boost(&self, account: &str, now: f64) -> i64 {
        (self.factor(account, now) * 1000.0) as i64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unused_account_gets_full_factor() {
        let mut fs = FairShare::new(3600.0);
        fs.charge("hog", 100_000.0, 0.0);
        // "newbie" has no usage at all
        assert!(fs.factor("newbie", 0.0) > 0.99);
        assert!(fs.factor("hog", 0.0) < 0.6);
    }

    #[test]
    fn usage_decays_with_half_life() {
        let mut fs = FairShare::new(100.0);
        fs.charge("a", 1000.0, 0.0);
        let f0 = fs.factor("a", 0.0);
        let f1 = fs.factor("a", 100.0); // one half-life later
        // decayed usage is still 100% of *total* usage (only account), so
        // factor depends on normalized usage: equal. Add a second account
        // to make decay observable.
        fs.charge("b", 1000.0, 100.0);
        let fa = fs.factor("a", 100.0);
        let fb = fs.factor("b", 100.0);
        assert!(fa > fb, "a decayed ({fa}) should beat b fresh ({fb})");
        assert!(f0 <= f1 + 1e-9);
    }

    #[test]
    fn heavier_user_ranks_below_lighter_user() {
        let mut fs = FairShare::new(3600.0);
        fs.charge("heavy", 50_000.0, 10.0);
        fs.charge("light", 5_000.0, 10.0);
        assert!(fs.priority_boost("light", 10.0) > fs.priority_boost("heavy", 10.0));
    }

    #[test]
    fn shares_weight_the_factor() {
        let mut fs = FairShare::new(3600.0);
        fs.set_shares("vip", 9.0);
        fs.set_shares("std", 1.0);
        fs.charge("vip", 10_000.0, 0.0);
        fs.charge("std", 10_000.0, 0.0);
        // same usage, but vip owns 90% of shares -> higher factor
        assert!(fs.factor("vip", 0.0) > fs.factor("std", 0.0));
    }

    #[test]
    fn partition_inventory() {
        let p = Partition::batch(100);
        assert_eq!(p.len(), 100);
        assert!(!p.is_empty());
        let interactive = Partition {
            name: "interactive".into(),
            nodes: 96..100,
            max_nodes_per_account: Some(1),
        };
        assert_eq!(interactive.len(), 4);
    }

    #[test]
    fn fairshare_scheduler_integration() {
        // run two accounts through the SlurmSim using fairshare-boosted
        // priorities; the light user's job jumps the heavy user's queue
        use crate::config::ClusterConfig;
        use crate::scheduler::{Job, SlurmSim};
        let cfg = ClusterConfig::default();
        let mut fs = FairShare::new(3600.0);
        fs.charge("heavy", 200_000.0, 0.0);
        fs.charge("light", 1_000.0, 0.0);

        let mut sim = SlurmSim::new(&cfg);
        // both jobs need the whole machine; submitted together
        sim.submit(
            Job::new(1, "heavy-job", 100, 100.0, 50.0)
                .with_priority(fs.priority_boost("heavy", 0.0)),
        );
        sim.submit(
            Job::new(2, "light-job", 100, 100.0, 50.0)
                .with_priority(fs.priority_boost("light", 0.0)),
        );
        sim.run();
        let light = sim.history.iter().find(|a| a.job_id == 2).unwrap();
        let heavy = sim.history.iter().find(|a| a.job_id == 1).unwrap();
        assert!(light.start < heavy.start);
    }
}
