//! Event-driven Slurm-like scheduler: priority FIFO with conservative
//! backfill and rail-aware placement.

use std::collections::BTreeMap;

use super::job::{Allocation, Job, JobState};
use super::placement::place;
use crate::config::ClusterConfig;

#[derive(Debug, Clone)]
pub struct SchedulerStats {
    pub completed: usize,
    pub backfilled: usize,
    pub mean_wait: f64,
    pub max_wait: f64,
    pub makespan: f64,
    /// node-seconds busy / node-seconds available
    pub utilization: f64,
    pub single_pod_fraction: f64,
}

pub struct SlurmSim {
    pub cfg: ClusterConfig,
    jobs: BTreeMap<u64, Job>,
    pending: Vec<u64>,
    running: Vec<Allocation>,
    pub history: Vec<Allocation>,
    free: Vec<usize>,
    now: f64,
    waits: Vec<f64>,
    backfilled: usize,
    single_pod: usize,
    backfill_enabled: bool,
}

impl SlurmSim {
    pub fn new(cfg: &ClusterConfig) -> Self {
        Self {
            cfg: cfg.clone(),
            jobs: BTreeMap::new(),
            pending: Vec::new(),
            running: Vec::new(),
            history: Vec::new(),
            free: (0..cfg.nodes).collect(),
            now: 0.0,
            waits: Vec::new(),
            backfilled: 0,
            single_pod: 0,
            backfill_enabled: true,
        }
    }

    /// Toggle conservative backfill (on by default). With backfill off
    /// the queue is strict priority FIFO: nothing starts past a blocked
    /// head — the `fifo` end of the trace-replay policy sweep
    /// (`scheduler::trace`).
    pub fn set_backfill(&mut self, on: bool) {
        self.backfill_enabled = on;
    }

    /// Per-job queue waits (seconds), in start order — the sample the
    /// trace-replay reports take percentiles over.
    pub fn waits(&self) -> &[f64] {
        &self.waits
    }

    pub fn submit(&mut self, job: Job) {
        assert!(job.nodes <= self.cfg.nodes, "job larger than cluster");
        self.pending.push(job.id);
        self.jobs.insert(job.id, job);
    }

    fn sort_pending(&mut self) {
        let jobs = &self.jobs;
        self.pending.sort_by(|a, b| {
            let ja = &jobs[a];
            let jb = &jobs[b];
            jb.priority
                .cmp(&ja.priority)
                .then(ja.submit_time.partial_cmp(&jb.submit_time).unwrap())
                .then(ja.id.cmp(&jb.id))
        });
    }

    /// Earliest time the head job could start, given running allocations
    /// (conservative reservation for backfill).
    fn head_reservation(&self, want: usize) -> f64 {
        if self.free.len() >= want {
            return self.now;
        }
        let mut ends: Vec<(f64, usize)> =
            self.running.iter().map(|a| (a.end, a.nodes.len())).collect();
        ends.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let mut avail = self.free.len();
        for (end, n) in ends {
            avail += n;
            if avail >= want {
                return end;
            }
        }
        f64::INFINITY
    }

    /// Try to start pending jobs at `self.now`. FIFO head first; then
    /// backfill any job that fits now AND finishes (by its limit) before
    /// the head job's reservation.
    fn schedule(&mut self) {
        self.sort_pending();
        let mut i = 0;
        let mut head_blocked: Option<f64> = None;
        while i < self.pending.len() {
            let id = self.pending[i];
            let job = self.jobs[&id].clone();
            if job.submit_time > self.now {
                i += 1;
                continue;
            }
            let can_place = self.free.len() >= job.nodes;
            match head_blocked {
                None => {
                    if can_place {
                        self.start(&job);
                        self.pending.remove(i);
                    } else {
                        head_blocked = Some(self.head_reservation(job.nodes));
                        i += 1;
                    }
                }
                Some(resv) => {
                    // backfill: must fit now and not delay the reservation
                    if self.backfill_enabled
                        && can_place
                        && self.now + job.time_limit <= resv
                    {
                        self.start(&job);
                        self.pending.remove(i);
                        self.backfilled += 1;
                    } else {
                        i += 1;
                    }
                }
            }
        }
    }

    fn start(&mut self, job: &Job) {
        let placement = place(&self.cfg, &self.free, job.nodes)
            .expect("schedule() checked capacity");
        if placement.pods_spanned == 1 {
            self.single_pod += 1;
        }
        self.free.retain(|n| !placement.nodes.contains(n));
        self.waits.push(self.now - job.submit_time);
        let alloc = Allocation {
            job_id: job.id,
            nodes: placement.nodes,
            start: self.now,
            end: self.now + job.runtime,
        };
        self.jobs.get_mut(&job.id).unwrap().state = JobState::Running;
        self.running.push(alloc);
    }

    /// Advance to the next event (job end or future submit) and schedule.
    /// Returns false when nothing remains.
    pub fn step(&mut self) -> bool {
        // complete anything ending now or earlier is handled after advance
        if self.running.is_empty() && self.pending.is_empty() {
            return false;
        }
        // next event time
        let mut t_next = f64::INFINITY;
        for a in &self.running {
            t_next = t_next.min(a.end);
        }
        for id in &self.pending {
            let st = self.jobs[id].submit_time;
            if st > self.now {
                t_next = t_next.min(st);
            }
        }
        if self.running.is_empty() {
            // all pending are future submits
            self.now = t_next;
        } else {
            self.now = t_next;
            // retire finished allocations
            let mut i = 0;
            while i < self.running.len() {
                if self.running[i].end <= self.now + 1e-9 {
                    let a = self.running.swap_remove(i);
                    self.free.extend(a.nodes.iter().cloned());
                    self.free.sort_unstable();
                    self.jobs.get_mut(&a.job_id).unwrap().state =
                        JobState::Completed;
                    self.history.push(a);
                } else {
                    i += 1;
                }
            }
        }
        self.schedule();
        true
    }

    /// Run to completion.
    pub fn run(&mut self) -> SchedulerStats {
        self.schedule();
        while self.step() {}
        let completed = self.history.len();
        let makespan = self.history.iter().map(|a| a.end).fold(0.0, f64::max);
        let busy: f64 = self
            .history
            .iter()
            .map(|a| (a.end - a.start) * a.nodes.len() as f64)
            .sum();
        let avail = makespan * self.cfg.nodes as f64;
        SchedulerStats {
            completed,
            backfilled: self.backfilled,
            mean_wait: crate::util::stats::mean(&self.waits),
            max_wait: crate::util::stats::max(&self.waits).max(0.0),
            makespan,
            utilization: if avail > 0.0 { busy / avail } else { 0.0 },
            single_pod_fraction: if completed > 0 {
                self.single_pod as f64 / completed as f64
            } else {
                0.0
            },
        }
    }

    pub fn job_state(&self, id: u64) -> Option<JobState> {
        self.jobs.get(&id).map(|j| j.state)
    }

    pub fn free_nodes(&self) -> usize {
        self.free.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::job::Job;

    fn sim() -> SlurmSim {
        SlurmSim::new(&ClusterConfig::default())
    }

    #[test]
    fn single_job_runs_immediately() {
        let mut s = sim();
        s.submit(Job::new(1, "a", 10, 100.0, 50.0));
        let stats = s.run();
        assert_eq!(stats.completed, 1);
        assert_eq!(stats.mean_wait, 0.0);
        assert!((stats.makespan - 50.0).abs() < 1e-9);
    }

    #[test]
    fn serial_when_cluster_full() {
        let mut s = sim();
        s.submit(Job::new(1, "big1", 100, 100.0, 100.0));
        s.submit(Job::new(2, "big2", 100, 100.0, 100.0));
        let stats = s.run();
        assert_eq!(stats.completed, 2);
        assert!((stats.makespan - 200.0).abs() < 1e-9);
        assert!((stats.utilization - 1.0).abs() < 1e-9);
    }

    #[test]
    fn backfill_fills_the_hole() {
        let mut s = sim();
        // 60-node job running 100s; head job needs 100 nodes (waits);
        // a small short job can backfill meanwhile.
        s.submit(Job::new(1, "wide", 60, 200.0, 100.0));
        s.submit(Job::new(2, "head", 100, 200.0, 10.0).with_submit_time(1.0));
        s.submit(Job::new(3, "small", 10, 50.0, 50.0).with_submit_time(2.0));
        let stats = s.run();
        assert_eq!(stats.completed, 3);
        assert_eq!(stats.backfilled, 1);
        // small starts at ~2 (backfilled), not after head
        let small = s.history.iter().find(|a| a.job_id == 3).unwrap();
        assert!(small.start < 10.0, "start={}", small.start);
    }

    #[test]
    fn backfill_off_forces_strict_fifo() {
        let mut s = sim();
        s.set_backfill(false);
        // same workload as backfill_fills_the_hole: with the toggle off
        // the small job must queue behind the blocked head instead.
        s.submit(Job::new(1, "wide", 60, 200.0, 100.0));
        s.submit(Job::new(2, "head", 100, 200.0, 10.0).with_submit_time(1.0));
        s.submit(Job::new(3, "small", 10, 50.0, 50.0).with_submit_time(2.0));
        let stats = s.run();
        assert_eq!(stats.completed, 3);
        assert_eq!(stats.backfilled, 0);
        let small = s.history.iter().find(|a| a.job_id == 3).unwrap();
        assert!(small.start >= 110.0, "start={}", small.start);
        assert_eq!(s.waits().len(), 3);
    }

    #[test]
    fn backfill_never_delays_head() {
        let mut s = sim();
        s.submit(Job::new(1, "wide", 60, 200.0, 100.0));
        s.submit(Job::new(2, "head", 100, 200.0, 10.0).with_submit_time(1.0));
        // long small job must NOT backfill (would delay head's reservation)
        s.submit(Job::new(3, "long-small", 10, 500.0, 400.0).with_submit_time(2.0));
        s.run();
        let head = s.history.iter().find(|a| a.job_id == 2).unwrap();
        assert!((head.start - 100.0).abs() < 1e-6, "head delayed: {}", head.start);
    }

    #[test]
    fn priority_order_respected() {
        let mut s = sim();
        s.submit(Job::new(1, "lo", 100, 100.0, 10.0));
        s.submit(Job::new(2, "hi", 100, 100.0, 10.0).with_priority(5));
        // both pending at t=0; hi should run first
        let stats = s.run();
        assert_eq!(stats.completed, 2);
        let hi = s.history.iter().find(|a| a.job_id == 2).unwrap();
        let lo = s.history.iter().find(|a| a.job_id == 1).unwrap();
        assert!(hi.start < lo.start);
    }

    #[test]
    fn future_submits_wait() {
        let mut s = sim();
        s.submit(Job::new(1, "later", 10, 10.0, 5.0).with_submit_time(100.0));
        let stats = s.run();
        assert_eq!(stats.completed, 1);
        let a = &s.history[0];
        assert!((a.start - 100.0).abs() < 1e-9);
    }

    #[test]
    fn utilization_reasonable_for_random_mix() {
        use crate::util::rng::Rng;
        let mut s = sim();
        let mut rng = Rng::new(42);
        for id in 0..200 {
            let nodes = 1 + rng.below(32) as usize;
            let rt = rng.range(10.0, 500.0);
            s.submit(
                Job::new(id, "mix", nodes, rt * 1.5, rt)
                    .with_submit_time(rng.range(0.0, 1000.0)),
            );
        }
        let stats = s.run();
        assert_eq!(stats.completed, 200);
        assert!(stats.utilization > 0.5, "util={}", stats.utilization);
        // best-fit pod packing keeps most allocations rail-local even on a
        // busy fragmented cluster
        assert!(
            stats.single_pod_fraction > 0.7,
            "single-pod fraction {}",
            stats.single_pod_fraction
        );
    }

    #[test]
    #[should_panic(expected = "job larger than cluster")]
    fn oversized_job_rejected() {
        let mut s = sim();
        s.submit(Job::new(1, "too-big", 101, 10.0, 5.0));
    }
}
