//! Paper-vs-measured comparison reports: the EXPERIMENTS.md backbone.

use super::hpcg::HpcgResult;
use super::hpl::HplResult;
use super::hpl_mxp::MxpResult;
use super::io500::Io500Result;
use crate::util::table::Table;

/// Paper values for the four headline experiments.
pub mod paper {
    pub const HPL_RMAX_PF: f64 = 33.95;
    pub const HPL_TIME_S: f64 = 389.23;
    pub const HPL_PER_GPU_TF: f64 = 43.31;
    pub const HPL_MAX_GEMM_TF: f64 = 55.34;

    pub const HPCG_RAW_GF: f64 = 437_361.0;
    pub const HPCG_CONV_GF: f64 = 404_964.0;
    pub const HPCG_FINAL_GF: f64 = 396_295.0;
    pub const HPCG_BW_TBS: f64 = 3.316;

    pub const MXP_RMAX_PF: f64 = 339.86;
    pub const MXP_PER_GPU_TF: f64 = 442.52;
    pub const MXP_LU_PF: f64 = 539.19;
    pub const MXP_LU_PER_GPU_TF: f64 = 702.07;

    pub const IO500_10N_TOTAL: f64 = 181.91;
    pub const IO500_96N_TOTAL: f64 = 214.09;
    pub const IO500_10N_BW: f64 = 133.03;
    pub const IO500_96N_BW: f64 = 139.80;
    pub const IO500_10N_IOPS: f64 = 248.74;
    pub const IO500_96N_IOPS: f64 = 327.84;
}

fn row(name: &str, paper: f64, measured: f64) -> (String, String, String, String) {
    (
        name.to_string(),
        format!("{paper:.2}"),
        format!("{measured:.2}"),
        format!("{:+.1}%", 100.0 * (measured - paper) / paper),
    )
}

fn table_from(title: &str, rows: Vec<(String, String, String, String)>) -> Table {
    let mut t = Table::new(title, &["Metric", "Paper", "Measured", "Delta"]);
    for (a, b, c, d) in rows {
        t.row(&[a, b, c, d]);
    }
    t
}

pub fn hpl_compare(r: &HplResult) -> Table {
    table_from(
        "T7 HPL: paper vs simulated",
        vec![
            row("Rmax (PFLOP/s)", paper::HPL_RMAX_PF, r.rmax / 1e15),
            row("Execution time (s)", paper::HPL_TIME_S, r.time_s),
            row("Per-GPU (TFLOP/s)", paper::HPL_PER_GPU_TF, r.rmax_per_gpu / 1e12),
            row(
                "Max GEMM (TFLOP/s)",
                paper::HPL_MAX_GEMM_TF,
                r.max_gemm_per_gpu / 1e12,
            ),
        ],
    )
}

pub fn hpcg_compare(r: &HpcgResult) -> Table {
    table_from(
        "T8 HPCG: paper vs simulated",
        vec![
            row("Raw (GFLOP/s)", paper::HPCG_RAW_GF, r.raw_gflops),
            row(
                "Convergence-adjusted (GFLOP/s)",
                paper::HPCG_CONV_GF,
                r.convergence_gflops,
            ),
            row("Final validated (GFLOP/s)", paper::HPCG_FINAL_GF, r.final_gflops),
            row(
                "Observed BW (TB/s per GPU)",
                paper::HPCG_BW_TBS,
                r.observed_bw_per_gpu / 1e12,
            ),
        ],
    )
}

pub fn mxp_compare(r: &MxpResult) -> Table {
    table_from(
        "T9 HPL-MxP: paper vs simulated",
        vec![
            row("Rmax (PFLOP/s)", paper::MXP_RMAX_PF, r.rmax / 1e15),
            row("Rmax per GPU (TFLOP/s)", paper::MXP_PER_GPU_TF, r.rmax_per_gpu / 1e12),
            row("LU-only (PFLOP/s)", paper::MXP_LU_PF, r.lu_only / 1e15),
            row(
                "LU-only per GPU (TFLOP/s)",
                paper::MXP_LU_PER_GPU_TF,
                r.lu_only_per_gpu / 1e12,
            ),
        ],
    )
}

pub fn io500_compare(r10: &Io500Result, r96: &Io500Result) -> Table {
    table_from(
        "T10 IO500: paper vs simulated",
        vec![
            row("10-node total", paper::IO500_10N_TOTAL, r10.total_score),
            row("10-node BW (GiB/s)", paper::IO500_10N_BW, r10.bw_score_gib),
            row("10-node IOPS (kIOPS)", paper::IO500_10N_IOPS, r10.iops_score_k),
            row("96-node total", paper::IO500_96N_TOTAL, r96.total_score),
            row("96-node BW (GiB/s)", paper::IO500_96N_BW, r96.bw_score_gib),
            row("96-node IOPS (kIOPS)", paper::IO500_96N_IOPS, r96.iops_score_k),
        ],
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::hpl::{run_hpl, HplParams};
    use crate::config::ClusterConfig;

    #[test]
    fn compare_table_has_delta_column() {
        let cfg = ClusterConfig::default();
        let r = run_hpl(&cfg, &HplParams::paper());
        let s = hpl_compare(&r).render();
        assert!(s.contains("Delta"));
        assert!(s.contains("Rmax (PFLOP/s)"));
        assert!(s.contains('%'));
    }

    #[test]
    fn paper_constants_internally_consistent() {
        // per-GPU x 784 == Rmax for HPL
        let total = paper::HPL_PER_GPU_TF * 784.0 / 1000.0;
        assert!((total - paper::HPL_RMAX_PF).abs() / paper::HPL_RMAX_PF < 0.01);
        // IO500 total = sqrt(bw * iops)
        let t10 = (paper::IO500_10N_BW * paper::IO500_10N_IOPS).sqrt();
        assert!((t10 - paper::IO500_10N_TOTAL).abs() < 0.5);
    }
}
