//! TOP500 context data (paper §2.2 Table 3 and §5 Discussion).
//!
//! Table 3 is a census, not a measurement: interconnect families of the
//! top-10 systems of the Nov-2024 list by the year each system entered.
//! We embed the dataset and regenerate the table, plus the ranking
//! context the discussion quotes (SAKURAONE: #49 TOP500, #12 HPL-MxP,
//! #9 IO500 10-node production).

use crate::util::table::Table;

#[derive(Debug, Clone)]
pub struct InterconnectEntry {
    pub family: &'static str,
    /// Systems entering the top-10 in 2020..=2024 (Nov-2024 list).
    pub by_year: [u32; 5],
}

impl InterconnectEntry {
    pub fn total(&self) -> u32 {
        self.by_year.iter().sum()
    }
}

/// Table 3 dataset (paper values, Nov-2024 top-10).
pub fn interconnect_census() -> Vec<InterconnectEntry> {
    vec![
        InterconnectEntry { family: "Gigabit Ethernet", by_year: [0, 1, 0, 2, 4] },
        InterconnectEntry { family: "Slingshot-11", by_year: [0, 1, 0, 2, 4] },
        InterconnectEntry { family: "Infiniband", by_year: [0, 0, 0, 2, 0] },
        InterconnectEntry {
            family: "NVIDIA Infiniband NDR",
            by_year: [0, 0, 0, 1, 0],
        },
        InterconnectEntry {
            family: "Quad-rail NVIDIA HDR100 Infiniband",
            by_year: [0, 0, 0, 1, 0],
        },
        InterconnectEntry { family: "Proprietary Network", by_year: [1, 0, 0, 0, 0] },
        InterconnectEntry { family: "Tofu interconnect D", by_year: [1, 0, 0, 0, 0] },
    ]
}

pub fn census_table() -> Table {
    let mut t = Table::new(
        "Table 3 — Interconnect usage (2020-2024) in top 10 of Nov-2024 TOP500",
        &["Interconnect", "2020", "2021", "2022", "2023", "2024", "Total"],
    );
    let census = interconnect_census();
    for e in &census {
        let mut row = vec![e.family.to_string()];
        row.extend(e.by_year.iter().map(|c| {
            if *c == 0 {
                String::new()
            } else {
                c.to_string()
            }
        }));
        row.push(e.total().to_string());
        t.row(&row);
    }
    // column totals
    let mut totals = vec!["Total".to_string()];
    for y in 0..5 {
        let s: u32 = census.iter().map(|e| e.by_year[y]).sum();
        totals.push(if s == 0 { String::new() } else { s.to_string() });
    }
    // note: the top-10 has 10 slots; the Ethernet/Slingshot rows
    // double-count hybrid systems exactly as the paper's table does.
    let grand: u32 = census.iter().map(|e| e.total()).sum();
    totals.push(grand.to_string());
    t.row(&totals);
    t
}

/// The paper's headline ranking claims (ISC 2025 lists).
#[derive(Debug, Clone)]
pub struct RankingClaims {
    pub top500_rank: u32,
    pub hpl_mxp_rank: u32,
    pub io500_10node_rank: u32,
    pub io500_10node_rank_japan: u32,
    pub only_sonic_in_top100: bool,
}

pub fn paper_rankings() -> RankingClaims {
    RankingClaims {
        top500_rank: 49,
        hpl_mxp_rank: 12,
        io500_10node_rank: 9,
        io500_10node_rank_japan: 2,
        only_sonic_in_top100: true,
    }
}

pub fn rankings_table() -> Table {
    let r = paper_rankings();
    let mut t = Table::new(
        "SAKURAONE rankings (ISC 2025, paper §5)",
        &["List", "Rank"],
    );
    t.row(&["TOP500 (HPL)", &format!("#{}", r.top500_rank)]);
    t.row(&["HPL-MxP", &format!("#{}", r.hpl_mxp_rank)]);
    t.row(&[
        "IO500 10-Node Production",
        &format!("#{} (#{} in Japan)", r.io500_10node_rank, r.io500_10node_rank_japan),
    ]);
    t.row(&[
        "SONiC-based Ethernet in TOP100",
        if r.only_sonic_in_top100 { "only system" } else { "-" },
    ]);
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn census_totals_match_paper() {
        let c = interconnect_census();
        let gbe = c.iter().find(|e| e.family == "Gigabit Ethernet").unwrap();
        assert_eq!(gbe.total(), 7);
        let ss = c.iter().find(|e| e.family == "Slingshot-11").unwrap();
        assert_eq!(ss.total(), 7);
        let ib = c.iter().find(|e| e.family == "Infiniband").unwrap();
        assert_eq!(ib.total(), 2);
    }

    #[test]
    fn gbe_trend_is_increasing() {
        let c = interconnect_census();
        let gbe = c.iter().find(|e| e.family == "Gigabit Ethernet").unwrap();
        assert_eq!(gbe.by_year[4], 4); // 2024 cohort
        assert!(gbe.by_year[4] > gbe.by_year[1]);
    }

    #[test]
    fn table_renders_all_rows() {
        let s = census_table().render();
        assert!(s.contains("Tofu interconnect D"));
        assert!(s.contains("Slingshot-11"));
        assert!(s.contains("Total"));
    }

    #[test]
    fn rankings_as_published() {
        let r = paper_rankings();
        assert_eq!(r.top500_rank, 49);
        assert_eq!(r.hpl_mxp_rank, 12);
        assert!(rankings_table().render().contains("#49"));
    }
}
