//! HPL-MxP (mixed-precision LINPACK) on the simulated cluster — Table 9.
//!
//! HPL-MxP factors the matrix in low precision (the paper ran NVIDIA's
//! 'Sloppy FP8' mode, sloppy-type=1) and recovers FP64 accuracy with
//! GMRES-based iterative refinement. The benchmark is rated with the
//! *FP64 flop count* (2/3 N^3) over the *total* time, which is why the
//! paper reports both the overall Rmax (339.86 PF) and the much higher
//! LU-only rate (539.19 PF): the IR phase is bandwidth-bound and eats
//! ~40% of the wall clock while contributing almost no rated flops.
//!
//! Structure mirrors `hpl.rs` with the trailing update on the FP8 tensor
//! pipe; the IR phase is modelled as GMRES iterations of matvec + two
//! triangular solves, all HBM-bandwidth-bound, plus global reductions.
//!
//! Numerics: the AOT artifact `mxp_solve_256` executes the same algorithm
//! (bf16 LU stand-in for FP8 + f32 IR) and must pass the identical
//! scaled-residual check the paper quotes (5.01e-5 < 16).

use crate::collectives::{CollectiveEngine, Rank};
use crate::config::ClusterConfig;
use crate::hardware::{GpuModel, Precision};
use crate::topology::builders::build;
use crate::util::table::kv_table;

#[derive(Debug, Clone, PartialEq)]
pub struct MxpParams {
    pub n: u64,
    pub nb: u64,
    pub p: usize,
    pub q: usize,
    pub stride: usize,
    /// GMRES-IR iterations to reach the FP64-accurate residual from a
    /// sloppy-FP8 factorisation (restarted GMRES(50), ~4 restarts).
    pub ir_iters: u32,
    /// HBM efficiency of the IR matvec / triangular-solve sweeps.
    pub ir_bw_eff: f64,
    /// HBM interference + exposed-broadcast calibration (as in HPL).
    pub interference: f64,
    pub bcast_exposed: f64,
}

impl MxpParams {
    /// The paper's Table 9 run: N=2,989,056, NB=4096, 24x32 grid, FP8.
    pub fn paper() -> Self {
        Self {
            n: 2_989_056,
            nb: 4096,
            p: 24,
            q: 32,
            stride: 4,
            ir_iters: 180,
            ir_bw_eff: 0.80,
            interference: 0.06,
            bcast_exposed: 0.30,
        }
    }

    pub fn ranks(&self) -> usize {
        self.p * self.q
    }
}

#[derive(Debug, Clone)]
pub struct MxpResult {
    pub params: MxpParams,
    pub total_time_s: f64,
    pub lu_time_s: f64,
    pub ir_time_s: f64,
    pub rmax: f64,
    pub rmax_per_gpu: f64,
    pub lu_only: f64,
    pub lu_only_per_gpu: f64,
}

pub fn run_mxp(cfg: &ClusterConfig, params: &MxpParams) -> MxpResult {
    let fabric = build(cfg);
    let engine = CollectiveEngine::new(&fabric, cfg);
    let gpu = GpuModel::h100_sxm();
    let ranks = params.ranks();
    assert!(
        ranks <= cfg.total_gpus(),
        "grid {}x{} needs {ranks} GPUs",
        params.p,
        params.q
    );

    let n = params.n as f64;
    let nb = params.nb as f64;
    let steps = (params.n / params.nb) as usize;
    let stride = params.stride.max(1);

    let col_ranks: Vec<Rank> = (0..params.p)
        .map(|p| (p / cfg.node.gpus_per_node, p % cfg.node.gpus_per_node))
        .collect();
    let row_ranks: Vec<Rank> = (0..params.q)
        .map(|q| {
            let r = q * params.p;
            (r / cfg.node.gpus_per_node, r % cfg.node.gpus_per_node)
        })
        .collect();

    // ---------------- LU phase (FP8 trailing updates) ----------------------
    let mut lu_time = 0.0f64;
    let mut dbg = [0.0f64; 5]; // up, pf, bc, ubc, swap
    let mut k_iter = 0usize;
    while k_iter < steps {
        let nk = n - (k_iter as f64) * nb;
        let weight = stride.min(steps - k_iter) as f64;

        // panel factorisation in FP16/BF16 on the owning column
        let rows_local = (nk / params.p as f64).max(nb);
        let t_pf = rows_local * nb * nb / (gpu.bf16_flops * 0.10)
            + nb * 1.0e-6 / 8.0;
        // panel broadcast (1-byte elements) along rows
        let t_bc = engine
            .ring_broadcast(&row_ranks, rows_local * nb * 1.0)
            .total;
        // U broadcast + swaps along columns
        let u_buf = nb * (nk / params.q as f64) * 1.0;
        let t_ubc = engine.ring_broadcast(&col_ranks, u_buf).total;
        let (t_swap_one, _) = engine.ring_step_time(&col_ranks, u_buf);
        let t_swap = 2.0 * t_swap_one;

        // trailing update on the FP8 pipe
        let m_loc = nk / params.p as f64;
        let n_loc = nk / params.q as f64;
        let t_up = gpu.gemm_time(m_loc, n_loc, nb, Precision::Fp8)
            * (1.0 + params.interference);

        // NB=4096 gives HPL-MxP ~6x more flops per panel than HPL's
        // NB=1024, so its deeper lookahead hides swaps and the U-broadcast
        // inside the update as well; only a fraction of the panel
        // broadcast stays exposed.
        let exposed = params.bcast_exposed * t_bc;
        let hidden = t_bc - exposed;
        lu_time += weight
            * (t_up.max(t_pf + hidden + t_swap + t_ubc) + exposed);
        dbg[0]+=weight*t_up; dbg[1]+=weight*t_pf; dbg[2]+=weight*t_bc; dbg[3]+=weight*t_ubc; dbg[4]+=weight*t_swap;
        k_iter += stride;
    }

    // ---------------- IR phase (GMRES on the FP64 residual) ----------------
    // per-rank slice of the dense matrix
    let a_bytes_local_f64 = n * n / ranks as f64 * 8.0;
    let bw = gpu.hbm_bw_bytes_per_s * params.ir_bw_eff;
    let t_matvec = a_bytes_local_f64 / bw;
    // two triangular solves stream half the matrix each at lower util
    let t_trsv = 2.0 * (a_bytes_local_f64 / 2.0) / (bw * 0.5);
    let all_ranks: Vec<Rank> = (0..ranks)
        .map(|r| (r / cfg.node.gpus_per_node, r % cfg.node.gpus_per_node))
        .collect();
    let t_red = engine.small_allreduce_latency(&all_ranks, 64.0)
        // pipelined row-sums of the distributed matvec
        + engine.ring_allreduce(&col_ranks, n / params.p as f64 * 8.0).total;
    let t_ir_iter = t_matvec + t_trsv + t_red;
    // setup: FP8 cast of A (read f64, write fp8) + norm computations
    let t_setup = (a_bytes_local_f64 * 1.125) / bw * 2.0;
    let ir_time = params.ir_iters as f64 * t_ir_iter + t_setup;

    if std::env::var("MXP_DEBUG").is_ok() {
        eprintln!("lu={lu_time:.2} up={:.2} pf={:.2} bc={:.2} ubc={:.2} swap={:.2} ir={ir_time:.2}", dbg[0], dbg[1], dbg[2], dbg[3], dbg[4]);
    }
    let total = lu_time + ir_time;
    let flops = 2.0 / 3.0 * n * n * n + 1.5 * n * n;
    MxpResult {
        params: params.clone(),
        total_time_s: total,
        lu_time_s: lu_time,
        ir_time_s: ir_time,
        rmax: flops / total,
        rmax_per_gpu: flops / total / ranks as f64,
        lu_only: flops / lu_time,
        lu_only_per_gpu: flops / lu_time / ranks as f64,
    }
}

impl MxpResult {
    pub fn table(&self) -> String {
        let gpu = GpuModel::h100_sxm();
        kv_table(
            "Table 9 — HPL-MxP Benchmark Summary (simulated)",
            &[
                (
                    "Benchmark version",
                    "sakuraone-sim (HPL-MxP-NVIDIA 25.4.0 model)".into(),
                ),
                ("Matrix size N", format!("{}", self.params.n)),
                ("Block size NB", format!("{}", self.params.nb)),
                (
                    "Process grid (PxQ)",
                    format!("{} x {}", self.params.p, self.params.q),
                ),
                ("Total processes", format!("{}", self.params.ranks())),
                ("Peak clock frequency", format!("{} MHz", gpu.peak_clock_mhz)),
                ("GPU SM version", "SM 90".into()),
                ("GPU SM count", format!("{}", gpu.sms)),
                (
                    "Observed Rmax",
                    format!("{:.4e} GFLOPS", self.rmax / 1e9),
                ),
                (
                    "Rmax per GPU",
                    format!("{:.2} GFLOPS", self.rmax_per_gpu / 1e9),
                ),
                ("LU-only", format!("{:.4e} GFLOPS", self.lu_only / 1e9)),
                (
                    "LU-only per GPU",
                    format!("{:.2} GFLOPS", self.lu_only_per_gpu / 1e9),
                ),
                (
                    "Precision mode",
                    "Sloppy FP8 (bf16 numerics stand-in; see DESIGN.md)".into(),
                ),
                (
                    "Time split (LU / IR)",
                    format!("{:.1} s / {:.1} s", self.lu_time_s, self.ir_time_s),
                ),
            ],
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overall_rmax_near_paper() {
        let r = run_mxp(&ClusterConfig::default(), &MxpParams::paper());
        let pf = r.rmax / 1e15;
        // Paper: 339.86 PFLOP/s
        assert!((pf - 339.86).abs() / 339.86 < 0.10, "Rmax {pf} PF");
    }

    #[test]
    fn lu_only_near_paper() {
        let r = run_mxp(&ClusterConfig::default(), &MxpParams::paper());
        let pf = r.lu_only / 1e15;
        // Paper: 539.19 PFLOP/s LU-only, 702.07 TF per GPU
        assert!((pf - 539.19).abs() / 539.19 < 0.12, "LU-only {pf} PF");
        let tf = r.lu_only_per_gpu / 1e12;
        assert!((tf - 702.07).abs() / 702.07 < 0.12, "{tf} TF/GPU");
    }

    #[test]
    fn mxp_speedup_over_hpl_is_order_ten() {
        // paper discussion: FP8 HPL-MxP ~10x the FP64 HPL result
        let cfg = ClusterConfig::default();
        let mxp = run_mxp(&cfg, &MxpParams::paper());
        let hpl = crate::benchmarks::hpl::run_hpl(
            &cfg,
            &crate::benchmarks::hpl::HplParams::paper(),
        );
        let speedup = mxp.rmax / hpl.rmax;
        assert!(speedup > 8.0 && speedup < 12.0, "speedup {speedup}");
    }

    #[test]
    fn ir_phase_is_substantial_but_minor_flops() {
        let r = run_mxp(&ClusterConfig::default(), &MxpParams::paper());
        let frac = r.ir_time_s / r.total_time_s;
        // paper implies ~37% of wall clock in IR (442.5/702.1 per-GPU ratio)
        assert!(frac > 0.25 && frac < 0.50, "IR frac {frac}");
    }

    #[test]
    fn fewer_ir_iters_raise_rmax() {
        let cfg = ClusterConfig::default();
        let mut p = MxpParams::paper();
        let base = run_mxp(&cfg, &p);
        p.ir_iters = 50;
        let fast = run_mxp(&cfg, &p);
        assert!(fast.rmax > base.rmax);
        assert!((fast.lu_only - base.lu_only).abs() / base.lu_only < 1e-9);
    }
}
