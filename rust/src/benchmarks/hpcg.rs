//! HPCG (High Performance Conjugate Gradients) on the simulated cluster —
//! Table 8.
//!
//! HPCG is bandwidth-bound: the 27-point stencil SpMV and the symmetric
//! Gauss-Seidel (SYMGS) smoother of the 4-level multigrid preconditioner
//! stream the sparse matrix from memory with ~0.15 flop/byte. The model
//! therefore derives every phase time from byte traffic over HBM, plus
//! halo exchanges (6 faces over the compute fabric) and latency-bound
//! global dot products.
//!
//! FLOP accounting follows HPCG 3.1 (2*27 flops per row per SpMV, two
//! sweeps per SYMGS, V(1,1) cycle over 4 levels with 8x coarsening), so
//! GFLOP/s emerges as flops / simulated time. The official score pipeline
//! (raw -> convergence-overhead-adjusted -> validated) is applied the way
//! the reference implementation does it.

use crate::collectives::{CollectiveEngine, Rank};
use crate::config::ClusterConfig;
use crate::hardware::GpuModel;
use crate::topology::builders::build;
use crate::util::table::kv_table;

#[derive(Debug, Clone, PartialEq)]
pub struct HpcgParams {
    /// Global problem dimensions.
    pub nx: u64,
    pub ny: u64,
    pub nz: u64,
    /// Rank grid factorisation (px*py*pz ranks).
    pub px: usize,
    pub py: usize,
    pub pz: usize,
    pub threads_per_process: usize,
    /// Achievable HBM fractions (stencil streaming vs dependency-stalled
    /// SYMGS sweeps).
    pub spmv_bw_eff: f64,
    pub symgs_bw_eff: f64,
    /// Reference CG iterations per set and the optimized implementation's
    /// count (extra iterations = convergence overhead, rated like HPCG 3.1).
    pub ref_iters: u32,
    pub opt_iters: u32,
    /// Multigrid levels (4 in HPCG 3.1).
    pub mg_levels: u32,
}

impl HpcgParams {
    /// The paper's Table 8 run: 4096x3584x3808 over 784 ranks.
    pub fn paper() -> Self {
        Self {
            nx: 4096,
            ny: 3584,
            nz: 3808,
            px: 8,
            py: 7,
            pz: 14,
            threads_per_process: 16,
            // HPCG-NVIDIA's multicolor-reordered SELL smoother streams at
            // essentially STREAM rate on H100.
            spmv_bw_eff: 0.99,
            symgs_bw_eff: 0.99,
            ref_iters: 50,
            opt_iters: 54,
            mg_levels: 4,
        }
    }

    pub fn ranks(&self) -> usize {
        self.px * self.py * self.pz
    }

    pub fn rows(&self) -> f64 {
        (self.nx * self.ny * self.nz) as f64
    }

    pub fn local_dims(&self) -> (f64, f64, f64) {
        (
            self.nx as f64 / self.px as f64,
            self.ny as f64 / self.py as f64,
            self.nz as f64 / self.pz as f64,
        )
    }
}

#[derive(Debug, Clone)]
pub struct HpcgResult {
    pub params: HpcgParams,
    pub equations: f64,
    pub nonzeros: f64,
    pub memory_bytes: f64,
    pub observed_bw_per_gpu: f64,
    pub raw_gflops: f64,
    pub convergence_gflops: f64,
    pub final_gflops: f64,
    pub time_per_iteration: f64,
    pub halo_frac: f64,
    pub allreduce_frac: f64,
}

/// Bytes per row streamed by one SpMV: 27 f64 values + 27 i32 column
/// indices (SELL-C-sigma layout, as in HPCG-NVIDIA); the x gather and y
/// write stay L2-resident between sweeps and are not re-streamed.
const SPMV_BYTES_PER_ROW: f64 = 324.0;
/// Flops per row per SpMV (27-point stencil multiply-add).
const SPMV_FLOPS_PER_ROW: f64 = 54.0;
/// Resident bytes per row (matrix + the CG/MG vector working set).
const MEMORY_BYTES_PER_ROW: f64 = 715.0;

pub fn run_hpcg(cfg: &ClusterConfig, params: &HpcgParams) -> HpcgResult {
    let fabric = build(cfg);
    let engine = CollectiveEngine::new(&fabric, cfg);
    let gpu = GpuModel::h100_sxm();
    let ranks = params.ranks();
    assert!(
        ranks <= cfg.total_gpus(),
        "HPCG wants {ranks} ranks, cluster has {} GPUs",
        cfg.total_gpus()
    );

    let rows_local = params.rows() / ranks as f64;
    let (lnx, lny, lnz) = params.local_dims();

    // --- per-level geometric series: level l has rows/8^l ------------------
    let level_scale: f64 = (0..params.mg_levels)
        .map(|l| 1.0f64 / 8f64.powi(l as i32))
        .sum();

    // --- HBM-bound compute phases ------------------------------------------
    let spmv_time = |rows: f64, eff: f64| {
        rows * SPMV_BYTES_PER_ROW / (gpu.hbm_bw_bytes_per_s * eff)
    };
    // fine-level SpMV (1 per iteration)
    let t_spmv = spmv_time(rows_local, params.spmv_bw_eff);
    // MG V(1,1): pre + post SYMGS (2 sweeps each) on every level, plus a
    // residual SpMV on all but the coarsest.
    let t_symgs_all = 2.0 * 2.0 * spmv_time(rows_local, params.symgs_bw_eff) * level_scale;
    let coarse_scale: f64 = (0..params.mg_levels - 1)
        .map(|l| 1.0f64 / 8f64.powi(l as i32))
        .sum();
    let t_mg_resid = spmv_time(rows_local, params.spmv_bw_eff) * coarse_scale;
    // WAXPBY vector updates: 3 per iteration, fused to 2 streams of
    // 8 B/row (read + write, the third operand rides in registers/L2)
    let t_waxpby = 3.0 * rows_local * 16.0
        / (gpu.hbm_bw_bytes_per_s * params.spmv_bw_eff);

    // --- halo exchanges ------------------------------------------------------
    // 6 faces, 8 B per boundary point, one exchange per fine SpMV/SYMGS
    // sweep; coarse levels shrink faces by 4x per level.
    let face_bytes = 2.0 * 8.0 * (lnx * lny + lny * lnz + lnx * lnz);
    let injection = cfg.node.compute_nic_gbps * 1e9 / 8.0
        * cfg.network.ethernet_efficiency
        * 0.95; // RoCE efficiency
    let halo_once = face_bytes / injection + 6.0 * 3.0e-6;
    let halo_scale: f64 = (0..params.mg_levels)
        .map(|l| 1.0f64 / 4f64.powi(l as i32))
        .sum();
    // exchanges: 1 (spmv) + per level (2 symgs sweeps) + residuals; half
    // the exchange is overlapped with interior compute (HPCG-NVIDIA packs
    // boundary planes and overlaps the interior sweep)
    let n_exchanges_fine_equiv = 1.0 + 2.0 * halo_scale + 1.0 * halo_scale;
    let t_halo = 0.5 * halo_once * n_exchanges_fine_equiv;

    // --- global reductions ---------------------------------------------------
    let all_ranks: Vec<Rank> = (0..ranks)
        .map(|r| (r / cfg.node.gpus_per_node, r % cfg.node.gpus_per_node))
        .collect();
    let t_dot = 3.0 * engine.small_allreduce_latency(&all_ranks, 8.0);

    let t_iter = t_spmv + t_symgs_all + t_mg_resid + t_waxpby + t_halo + t_dot;

    // --- HPCG 3.1 flop accounting -------------------------------------------
    let rows_global = params.rows();
    let f_spmv = SPMV_FLOPS_PER_ROW * rows_global;
    let f_symgs = 2.0 * SPMV_FLOPS_PER_ROW * rows_global; // fwd+bwd sweeps
    let f_mg = (2.0 * f_symgs) * level_scale + f_spmv * coarse_scale;
    let f_waxpby = 3.0 * 2.0 * rows_global;
    let f_dot = 3.0 * 2.0 * rows_global;
    let flops_iter = f_spmv + f_mg + f_waxpby + f_dot;

    let raw_gflops = flops_iter / t_iter / 1e9;
    // optimized run needs opt_iters to reach the reference residual ->
    // only the reference fraction counts (HPCG's convergence overhead)
    let convergence_gflops =
        raw_gflops * params.ref_iters as f64 / params.opt_iters as f64;
    // validated score: official runs rate the slowest of the timed sets
    let final_gflops = convergence_gflops * 0.9786;

    // memory + bandwidth observations
    let memory_bytes = rows_global * MEMORY_BYTES_PER_ROW;
    let bytes_iter_local = rows_local * SPMV_BYTES_PER_ROW
        + 4.0 * rows_local * SPMV_BYTES_PER_ROW * level_scale
        + rows_local * SPMV_BYTES_PER_ROW * coarse_scale
        + 3.0 * rows_local * 24.0;
    let observed_bw_per_gpu = bytes_iter_local / t_iter;

    HpcgResult {
        params: params.clone(),
        equations: rows_global,
        nonzeros: rows_global * 27.0,
        memory_bytes,
        observed_bw_per_gpu,
        raw_gflops,
        convergence_gflops,
        final_gflops,
        time_per_iteration: t_iter,
        halo_frac: t_halo / t_iter,
        allreduce_frac: t_dot / t_iter,
    }
}

impl HpcgResult {
    pub fn table(&self) -> String {
        kv_table(
            "Table 8 — HPCG Benchmark Summary (simulated)",
            &[
                ("Benchmark version", "sakuraone-sim (HPCG 3.1 model)".into()),
                (
                    "Total distributed processes",
                    format!("{}", self.params.ranks()),
                ),
                (
                    "Threads per process",
                    format!("{}", self.params.threads_per_process),
                ),
                (
                    "Global problem dimensions",
                    format!(
                        "{} x {} x {}",
                        self.params.nx, self.params.ny, self.params.nz
                    ),
                ),
                (
                    "Number of equations",
                    format!("{:.1} billion", self.equations / 1e9),
                ),
                (
                    "Number of nonzero terms",
                    format!("{:.2} trillion", self.nonzeros / 1e12),
                ),
                (
                    "Total memory used (GB)",
                    format!("{:.1}", self.memory_bytes / 1e9),
                ),
                (
                    "Peak memory bandwidth (observed, per GPU)",
                    format!("{:.3} TB/s", self.observed_bw_per_gpu / 1e12),
                ),
                (
                    "Total GFLOP/s (raw)",
                    format!("{:.0}", self.raw_gflops),
                ),
                (
                    "GFLOP/s (with convergence overhead)",
                    format!("{:.0}", self.convergence_gflops),
                ),
                (
                    "Final validated HPCG GFLOP/s result",
                    format!("{:.0}", self.final_gflops),
                ),
                (
                    "Time per CG iteration",
                    format!("{:.2} ms", self.time_per_iteration * 1e3),
                ),
                (
                    "Halo / allreduce share",
                    format!(
                        "{:.1}% / {:.1}%",
                        100.0 * self.halo_frac,
                        100.0 * self.allreduce_frac
                    ),
                ),
            ],
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_dimensions_derive_table8_inventory() {
        let p = HpcgParams::paper();
        assert_eq!(p.ranks(), 784);
        let r = run_hpcg(&ClusterConfig::default(), &p);
        // 55.9 billion equations, 1.51 trillion nonzeros, ~40 TB memory
        assert!((r.equations / 1e9 - 55.9).abs() < 0.1, "{}", r.equations);
        assert!((r.nonzeros / 1e12 - 1.51).abs() < 0.01);
        assert!((r.memory_bytes / 1e12 - 40.0).abs() < 1.0);
    }

    #[test]
    fn final_score_matches_paper_within_10pct() {
        let r = run_hpcg(&ClusterConfig::default(), &HpcgParams::paper());
        // Paper: raw 437361, convergence 404964, final 396295 GFLOP/s
        assert!(
            (r.final_gflops - 396_295.0).abs() / 396_295.0 < 0.10,
            "final {}",
            r.final_gflops
        );
        assert!(r.raw_gflops > r.convergence_gflops);
        assert!(r.convergence_gflops > r.final_gflops);
    }

    #[test]
    fn observed_bandwidth_near_hbm_peak() {
        let r = run_hpcg(&ClusterConfig::default(), &HpcgParams::paper());
        // paper reports 3.316 TB/s observed peak
        assert!(
            (r.observed_bw_per_gpu / 1e12 - 3.316).abs() < 0.35,
            "{} TB/s",
            r.observed_bw_per_gpu / 1e12
        );
    }

    #[test]
    fn hpcg_is_under_one_percent_of_hpl() {
        // the paper's discussion: HPCG ~0.8-1.2% of HPL
        let cfg = ClusterConfig::default();
        let hpcg = run_hpcg(&cfg, &HpcgParams::paper());
        let hpl = crate::benchmarks::hpl::run_hpl(
            &cfg,
            &crate::benchmarks::hpl::HplParams::paper(),
        );
        let ratio = hpcg.final_gflops * 1e9 / hpl.rmax;
        assert!(ratio > 0.005 && ratio < 0.02, "ratio {ratio}");
    }

    #[test]
    fn compute_dominates_halo() {
        let r = run_hpcg(&ClusterConfig::default(), &HpcgParams::paper());
        assert!(r.halo_frac < 0.2, "halo {}", r.halo_frac);
        assert!(r.allreduce_frac < 0.05);
    }

    #[test]
    fn smaller_cluster_scales_down() {
        let mut cfg = ClusterConfig::default();
        cfg.apply_override("nodes", "16").unwrap();
        let p = HpcgParams {
            nx: 1024,
            ny: 1024,
            nz: 512,
            px: 4,
            py: 4,
            pz: 8,
            ..HpcgParams::paper()
        };
        let r = run_hpcg(&cfg, &p);
        let full = run_hpcg(&ClusterConfig::default(), &HpcgParams::paper());
        let per_rank_small = r.final_gflops / p.ranks() as f64;
        let per_rank_full = full.final_gflops / 784.0;
        // per-rank performance roughly scale-invariant (weak scaling)
        assert!(
            (per_rank_small - per_rank_full).abs() / per_rank_full < 0.25,
            "{per_rank_small} vs {per_rank_full}"
        );
    }
}
