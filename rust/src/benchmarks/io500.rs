//! IO500 benchmark suite over the Lustre model — Table 10.
//!
//! Twelve phases in the official order: ior-easy / mdtest-easy /
//! ior-hard / mdtest-hard write-side first (stonewalled at 300 s), then
//! find and the read/stat/delete phases over the data the write phases
//! produced. Scores follow Kunkel et al.: bandwidth score = geometric
//! mean of the four ior GiB/s results, IOPS score = geometric mean of the
//! eight metadata kIOPS results, total = sqrt(bw * iops).

use crate::config::ClusterConfig;
use crate::storage::{LustreModel, MetaOp, StripePlan};
use crate::util::stats::geomean;
use crate::util::table::Table;
use crate::util::units::GIB;

/// ior-hard record size (bytes) — fixed by the benchmark definition.
pub const IOR_HARD_RECORD: f64 = 47_008.0;
/// Write-phase stonewall (seconds).
pub const STONEWALL_S: f64 = 300.0;

#[derive(Debug, Clone, PartialEq)]
pub struct Io500Params {
    pub client_nodes: usize,
    pub procs_per_node: usize,
    /// Cap on files each mdtest process creates.
    pub files_per_proc: usize,
    pub seed: u64,
}

impl Io500Params {
    /// Paper's "10 Node Production" entry: 10 nodes, 1280 processes.
    pub fn paper_10node() -> Self {
        Self { client_nodes: 10, procs_per_node: 128, files_per_proc: 100_000, seed: 42 }
    }

    /// Paper's 96-node run (same per-node process density).
    pub fn paper_96node() -> Self {
        Self { client_nodes: 96, procs_per_node: 128, files_per_proc: 100_000, seed: 42 }
    }

    pub fn procs(&self) -> usize {
        self.client_nodes * self.procs_per_node
    }
}

#[derive(Debug, Clone)]
pub struct PhaseResult {
    pub name: &'static str,
    /// GiB/s for ior phases, kIOPS for metadata phases.
    pub score: f64,
    pub unit: &'static str,
    pub duration_s: f64,
}

#[derive(Debug, Clone)]
pub struct Io500Result {
    pub params: Io500Params,
    pub phases: Vec<PhaseResult>,
    pub bw_score_gib: f64,
    pub iops_score_k: f64,
    pub total_score: f64,
}

pub fn run_io500(cfg: &ClusterConfig, params: &Io500Params) -> Io500Result {
    let model = LustreModel::sakuraone(&cfg.storage);
    run_io500_on(&model, params)
}

/// Run against an explicit Lustre model (lets tests inject a degraded one).
pub fn run_io500_on(model: &LustreModel, params: &Io500Params) -> Io500Result {
    let nodes = params.client_nodes;
    let procs = params.procs();
    let mut phases = Vec::new();

    // ---- ior-easy-write: file per process, large sequential ---------------
    // stripe each file over 1 OST. During the stonewalled phase every
    // process writes at whatever rate its OST grants, so the *aggregate*
    // is the contention-derated backend rate; placement imbalance only
    // stretches the post-stonewall drain (the busiest OST finishes last).
    let osts = model.cfg.servers * model.cfg.nvme_per_server;
    let plan = StripePlan::place(procs, 1, osts, params.seed);
    let w_bw = model.seq_write_bps(nodes, procs);
    let easy_write_bytes = w_bw * STONEWALL_S;
    phases.push(PhaseResult {
        name: "ior-easy-write",
        score: w_bw / GIB,
        unit: "GiB/s",
        duration_s: STONEWALL_S * (1.0 + (plan.imbalance() - 1.0) * 0.5) + 41.0,
    });

    // ---- mdtest-easy-write: create in per-proc directories ----------------
    let md_create = model.metadata_ops(MetaOp::Create, procs);
    let files_easy =
        (md_create * STONEWALL_S).min((params.files_per_proc * procs) as f64);
    phases.push(PhaseResult {
        name: "mdtest-easy-write",
        score: md_create / 1e3,
        unit: "kIOPS",
        duration_s: files_easy / md_create + 48.0,
    });

    // ---- ior-hard-write: shared file, 47008-byte interleaved records ------
    let hw_iops = model.shared_write_iops(procs);
    let hard_write_bytes = hw_iops * IOR_HARD_RECORD * STONEWALL_S;
    phases.push(PhaseResult {
        name: "ior-hard-write",
        score: hw_iops * IOR_HARD_RECORD / GIB,
        unit: "GiB/s",
        duration_s: STONEWALL_S + 55.0,
    });

    // ---- mdtest-hard-write: create in one shared directory ----------------
    let mdh_create = model.metadata_ops_hard(MetaOp::Create, procs);
    let files_hard =
        (mdh_create * STONEWALL_S).min((params.files_per_proc * procs) as f64);
    phases.push(PhaseResult {
        name: "mdtest-hard-write",
        score: mdh_create / 1e3,
        unit: "kIOPS",
        duration_s: files_hard / mdh_create + 38.0,
    });

    // ---- find: namespace scan over everything created ---------------------
    let total_files = files_easy + files_hard;
    let find_rate = model.metadata_ops(MetaOp::Find, procs);
    phases.push(PhaseResult {
        name: "find",
        score: find_rate / 1e3,
        unit: "kIOPS",
        duration_s: total_files / find_rate,
    });

    // ---- ior-easy-read -----------------------------------------------------
    let r_bw = model.seq_read_bps(nodes, procs);
    phases.push(PhaseResult {
        name: "ior-easy-read",
        score: r_bw / GIB,
        unit: "GiB/s",
        duration_s: easy_write_bytes / r_bw,
    });

    // ---- mdtest-easy-stat ----------------------------------------------------
    let md_stat = model.metadata_ops(MetaOp::Stat, procs);
    phases.push(PhaseResult {
        name: "mdtest-easy-stat",
        score: md_stat / 1e3,
        unit: "kIOPS",
        duration_s: files_easy / md_stat,
    });

    // ---- ior-hard-read -------------------------------------------------------
    let hr_iops = model.shared_read_iops(procs);
    phases.push(PhaseResult {
        name: "ior-hard-read",
        score: hr_iops * IOR_HARD_RECORD / GIB,
        unit: "GiB/s",
        duration_s: hard_write_bytes / (hr_iops * IOR_HARD_RECORD),
    });

    // ---- mdtest-hard-stat ------------------------------------------------------
    let mdh_stat = model.metadata_ops_hard(MetaOp::Stat, procs);
    phases.push(PhaseResult {
        name: "mdtest-hard-stat",
        score: mdh_stat / 1e3,
        unit: "kIOPS",
        duration_s: files_hard / mdh_stat,
    });

    // ---- mdtest-easy-delete ------------------------------------------------
    let md_del = model.metadata_ops(MetaOp::Delete, procs);
    phases.push(PhaseResult {
        name: "mdtest-easy-delete",
        score: md_del / 1e3,
        unit: "kIOPS",
        duration_s: files_easy / md_del,
    });

    // ---- mdtest-hard-read ----------------------------------------------------
    let mdh_read = model.metadata_ops_hard(MetaOp::Read, procs);
    phases.push(PhaseResult {
        name: "mdtest-hard-read",
        score: mdh_read / 1e3,
        unit: "kIOPS",
        duration_s: files_hard / mdh_read,
    });

    // ---- mdtest-hard-delete ---------------------------------------------------
    let mdh_del = model.metadata_ops_hard(MetaOp::Delete, procs);
    phases.push(PhaseResult {
        name: "mdtest-hard-delete",
        score: mdh_del / 1e3,
        unit: "kIOPS",
        duration_s: files_hard / mdh_del,
    });

    let bw: Vec<f64> = phases
        .iter()
        .filter(|p| p.unit == "GiB/s")
        .map(|p| p.score)
        .collect();
    let iops: Vec<f64> = phases
        .iter()
        .filter(|p| p.unit == "kIOPS")
        .map(|p| p.score)
        .collect();
    let bw_score = geomean(&bw);
    let iops_score = geomean(&iops);
    Io500Result {
        params: params.clone(),
        phases,
        bw_score_gib: bw_score,
        iops_score_k: iops_score,
        total_score: (bw_score * iops_score).sqrt(),
    }
}

impl Io500Result {
    pub fn table(&self) -> Table {
        let mut t = Table::new(
            &format!(
                "IO500 — {} nodes, {} processes (simulated)",
                self.params.client_nodes,
                self.params.procs()
            ),
            &["Benchmark", "Result", "Duration"],
        );
        for p in &self.phases {
            t.row(&[
                p.name.to_string(),
                format!("{:.2} {}", p.score, p.unit),
                format!("{:.2} s", p.duration_s),
            ]);
        }
        t.row(&[
            "Bandwidth Score".to_string(),
            format!("{:.2} GiB/s", self.bw_score_gib),
            String::new(),
        ]);
        t.row(&[
            "IOPS Score".to_string(),
            format!("{:.2} kIOPS", self.iops_score_k),
            String::new(),
        ]);
        t.row(&[
            "Total IO500 Score".to_string(),
            format!("{:.2}", self.total_score),
            String::new(),
        ]);
        t
    }

    pub fn phase(&self, name: &str) -> &PhaseResult {
        self.phases.iter().find(|p| p.name == name).unwrap()
    }
}

/// Table 10: side-by-side comparison of two runs.
pub fn comparison_table(a: &Io500Result, b: &Io500Result) -> Table {
    let mut t = Table::new(
        "Table 10 — IO500 results: 10 nodes vs 96 nodes (simulated)",
        &[
            "Benchmark",
            &format!("{} Nodes", a.params.client_nodes),
            &format!("{} Nodes", b.params.client_nodes),
        ],
    );
    for (pa, pb) in a.phases.iter().zip(&b.phases) {
        t.row(&[
            format!("{} ({})", pa.name, pa.unit),
            format!("{:.2} ({:.2} s)", pa.score, pa.duration_s),
            format!("{:.2} ({:.2} s)", pb.score, pb.duration_s),
        ]);
    }
    t.row(&[
        "Bandwidth Score (GiB/s)".into(),
        format!("{:.2}", a.bw_score_gib),
        format!("{:.2}", b.bw_score_gib),
    ]);
    t.row(&[
        "IOPS Score (kIOPS)".into(),
        format!("{:.2}", a.iops_score_k),
        format!("{:.2}", b.iops_score_k),
    ]);
    t.row(&[
        "Total IO500 Score".into(),
        format!("{:.2}", a.total_score),
        format!("{:.2}", b.total_score),
    ]);
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    fn results() -> (Io500Result, Io500Result) {
        let cfg = ClusterConfig::default();
        (
            run_io500(&cfg, &Io500Params::paper_10node()),
            run_io500(&cfg, &Io500Params::paper_96node()),
        )
    }

    #[test]
    fn total_scores_near_paper() {
        let (r10, r96) = results();
        // Paper: 181.91 (10 nodes) vs 214.09 (96 nodes)
        assert!(
            (r10.total_score - 181.91).abs() / 181.91 < 0.15,
            "10-node {}",
            r10.total_score
        );
        assert!(
            (r96.total_score - 214.09).abs() / 214.09 < 0.15,
            "96-node {}",
            r96.total_score
        );
        // headline shape: scaling out wins on total
        assert!(r96.total_score > r10.total_score);
    }

    #[test]
    fn easy_bandwidth_regresses_at_scale() {
        // the paper's counterintuitive crossover
        let (r10, r96) = results();
        assert!(
            r10.phase("ior-easy-write").score > r96.phase("ior-easy-write").score
        );
        assert!(
            r10.phase("ior-easy-read").score > r96.phase("ior-easy-read").score
        );
    }

    #[test]
    fn metadata_improves_at_scale() {
        let (r10, r96) = results();
        for name in [
            "mdtest-easy-write",
            "mdtest-easy-stat",
            "mdtest-hard-stat",
            "mdtest-hard-read",
            "find",
        ] {
            assert!(
                r96.phase(name).score > r10.phase(name).score,
                "{name} did not scale"
            );
        }
    }

    #[test]
    fn hard_ior_improves_at_scale() {
        let (r10, r96) = results();
        assert!(
            r96.phase("ior-hard-write").score > r10.phase("ior-hard-write").score
        );
        assert!(
            r96.phase("ior-hard-read").score > r10.phase("ior-hard-read").score
        );
    }

    #[test]
    fn ten_node_phase_values_close_to_paper() {
        let (r10, _) = results();
        let checks = [
            ("ior-easy-write", 262.91, 0.15),
            ("ior-easy-read", 365.71, 0.15),
            ("ior-hard-write", 15.84, 0.25),
            ("ior-hard-read", 205.64, 0.25),
            ("mdtest-easy-write", 204.44, 0.2),
            ("mdtest-easy-stat", 358.75, 0.2),
            ("find", 1976.05, 0.25),
        ];
        for (name, want, tol) in checks {
            let got = r10.phase(name).score;
            assert!(
                (got - want).abs() / want < tol,
                "{name}: got {got}, paper {want}"
            );
        }
    }

    #[test]
    fn bw_scores_close_but_iops_gap_wide() {
        // paper: bw 133.03 vs 139.80 (5%), iops 248.74 vs 327.84 (32%)
        let (r10, r96) = results();
        let bw_gap = r96.bw_score_gib / r10.bw_score_gib;
        let iops_gap = r96.iops_score_k / r10.iops_score_k;
        assert!(bw_gap > 0.9 && bw_gap < 1.25, "bw gap {bw_gap}");
        assert!(iops_gap > 1.15, "iops gap {iops_gap}");
        assert!(iops_gap > bw_gap);
    }

    #[test]
    fn degraded_switch_still_serves() {
        let cfg = ClusterConfig::default();
        let model = LustreModel::sakuraone(&cfg.storage).with_switch_failure();
        let r = run_io500_on(&model, &Io500Params::paper_10node());
        assert!(r.total_score > 0.0);
        let healthy = run_io500(&cfg, &Io500Params::paper_10node());
        assert!(r.total_score <= healthy.total_score);
    }

    #[test]
    fn twelve_phases_in_official_shape() {
        let (r10, _) = results();
        assert_eq!(r10.phases.len(), 12);
        assert_eq!(
            r10.phases.iter().filter(|p| p.unit == "GiB/s").count(),
            4
        );
        assert_eq!(
            r10.phases.iter().filter(|p| p.unit == "kIOPS").count(),
            8
        );
    }
}
