//! Benchmark drivers regenerating the paper's evaluation section:
//! HPL (T7), HPCG (T8), HPL-MxP (T9), IO500 (T10), the TOP500
//! interconnect census (T3), and paper-vs-measured comparison reports.

pub mod hpcg;
pub mod hpl;
pub mod hpl_mxp;
pub mod io500;
pub mod report;
pub mod top500;
