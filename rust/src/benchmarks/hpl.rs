//! HPL (High Performance Linpack) on the simulated cluster — Table 7.
//!
//! The driver walks HPL's actual execution structure: for each of the
//! N/NB block iterations over a P x Q process grid,
//!   1. the owning process column factors the panel (memory-bound,
//!      narrow rank-NB updates + pivot search),
//!   2. the panel is broadcast along process rows (ring-pipelined),
//!   3. pivot rows are swapped along process columns,
//!   4. every rank applies the trailing-submatrix DGEMM update
//!      (compute-bound — the FLOP carrier).
//! With lookahead, panel work for iteration k+1 overlaps the update of
//! iteration k, so per-iteration wall time is max(update, panel+bcast).
//!
//! Rank -> (node, GPU/rail) uses the same packing HPL-NVIDIA uses
//! (8 consecutive ranks per node), which makes process *rows* rail-local —
//! the traffic pattern SAKURAONE's rail-optimized fabric is built for.
//!
//! Numerics are validated separately through the AOT'd blocked-LU artifact
//! (`hpl_solve_256`) with HPL's own scaled-residual PASS criterion.

use crate::collectives::{CollectiveEngine, Rank};
use crate::config::ClusterConfig;
use crate::hardware::{GpuModel, Precision};
use crate::topology::builders::build;
use crate::util::table::kv_table;

#[derive(Debug, Clone, PartialEq)]
pub struct HplParams {
    pub n: u64,
    pub nb: u64,
    pub p: usize,
    pub q: usize,
    /// Simulate every `stride`-th iteration and integrate (1 = exact).
    pub stride: usize,
    /// HBM contention between the trailing update and concurrent
    /// NIC/NVLink DMA of the overlapped broadcasts (measured at 5-10% on
    /// H100 when NCCL rings run under compute); slows the update leg.
    pub interference: f64,
    /// Fraction of the panel broadcast that lookahead fails to hide
    /// (HPL-NVIDIA's 1-deep lookahead exposes the first row-ring hops).
    pub bcast_exposed: f64,
}

impl HplParams {
    /// The paper's Table 7 run: N=2,706,432, NB=1024, 16x49 grid.
    pub fn paper() -> Self {
        Self {
            n: 2_706_432,
            nb: 1024,
            p: 16,
            q: 49,
            stride: 8,
            interference: 0.06,
            bcast_exposed: 0.30,
        }
    }

    pub fn ranks(&self) -> usize {
        self.p * self.q
    }
}

#[derive(Debug, Clone)]
pub struct HplResult {
    pub params: HplParams,
    pub time_s: f64,
    pub flops: f64,
    pub rmax: f64,
    pub rmax_per_gpu: f64,
    pub max_gemm_per_gpu: f64,
    /// Fractions of wall time per phase.
    pub update_frac: f64,
    pub panel_frac: f64,
    pub comm_frac: f64,
}

/// Map a grid rank to (node, gpu/rail): 8 consecutive ranks per node.
pub fn rank_location(cfg: &ClusterConfig, rank: usize) -> (usize, usize) {
    let g = cfg.node.gpus_per_node;
    (rank / g, rank % g)
}

/// Grid coordinates: HPL default column-major rank order.
pub fn grid_coords(params: &HplParams, rank: usize) -> (usize, usize) {
    (rank % params.p, rank / params.p)
}

pub fn run_hpl(cfg: &ClusterConfig, params: &HplParams) -> HplResult {
    let fabric = build(cfg);
    let engine = CollectiveEngine::new(&fabric, cfg);
    let gpu = GpuModel::h100_sxm();
    let ranks = params.ranks();
    assert!(
        ranks <= cfg.total_gpus(),
        "grid {}x{} needs {ranks} GPUs, cluster has {}",
        params.p,
        params.q,
        cfg.total_gpus()
    );

    let n = params.n as f64;
    let nb = params.nb as f64;
    let steps = (params.n / params.nb) as usize;
    let stride = params.stride.max(1);

    // Pre-resolve the communication groups for a representative panel
    // column (process column 0) and row (process row 0).
    let col_ranks: Vec<Rank> = (0..params.p)
        .map(|p| rank_location(cfg, p)) // ranks p + 0*P = p
        .collect();
    let row_ranks: Vec<Rank> = (0..params.q)
        .map(|q| rank_location(cfg, q * params.p))
        .collect();

    let mut t_update = 0.0f64;
    let mut t_panel = 0.0f64;
    let mut t_comm = 0.0f64;
    let mut total = 0.0f64;
    let mut max_gemm_rate = 0.0f64;

    let mut k_iter = 0usize;
    while k_iter < steps {
        let nk = n - (k_iter as f64) * nb; // trailing size incl. this panel
        let weight = stride.min(steps - k_iter) as f64;

        // --- panel factorization (process column): rows_local x NB panel,
        // NB rank-1..rank-NB updates; memory-bound on the panel slab.
        let rows_local = (nk / params.p as f64).max(nb);
        let panel_flops = rows_local * nb * nb; // ~ nb^2 * rows updates
        let panel_bytes = rows_local * nb * 8.0 * (nb / 64.0).max(1.0) * 0.25;
        let t_pf = panel_flops / (gpu.fp64_vector_flops * 0.30)
            + panel_bytes / gpu.hbm_bw_bytes_per_s
            // pivot search/swap latency inside the column per sub-column
            + nb * 2.0e-6 / 8.0;

        // --- panel broadcast along the process row (rail-local ring)
        let panel_buf = rows_local * nb * 8.0;
        let t_bc = engine.ring_broadcast(&row_ranks, panel_buf).total;

        // --- pivot row swaps along the process column: rows travel both
        // directions (selected pivot rows out, replaced rows back)
        let swap_buf = nb * (nk / params.q as f64) * 8.0;
        let (t_swap_one, _) = engine.ring_step_time(&col_ranks, swap_buf);
        let t_swap = 2.0 * t_swap_one;

        // --- U broadcast down columns (the triangular solve result)
        let u_buf = nb * (nk / params.q as f64) * 8.0;
        let t_ubc = engine.ring_broadcast(&col_ranks, u_buf).total;

        // --- trailing update: local (nk/P) x (nk/Q) x NB DGEMM, slowed by
        // HBM interference from the overlapped communication DMA.
        let m_loc = nk / params.p as f64;
        let n_loc = nk / params.q as f64;
        let t_up = gpu.gemm_time(m_loc, n_loc, nb, Precision::Fp64Tensor)
            * (1.0 + params.interference);
        let rate = gpu.gemm_flops(m_loc, n_loc, nb, Precision::Fp64Tensor);
        if rate > max_gemm_rate {
            max_gemm_rate = rate;
        }

        // --- lookahead overlap: comm+panel hide behind the update while
        // the update is large; at the tail they dominate. A fraction of
        // the broadcast is always exposed (lookahead depth 1).
        let exposed_bc = params.bcast_exposed * t_bc;
        let hidden_bc = (1.0 - params.bcast_exposed) * t_bc;
        let critical = t_up.max(t_pf + hidden_bc) + exposed_bc + t_swap + t_ubc;
        total += weight * critical;
        t_update += weight * t_up;
        t_panel += weight * t_pf;
        t_comm += weight * (t_bc + t_swap + t_ubc);

        k_iter += stride;
    }

    let flops = 2.0 / 3.0 * n * n * n + 1.5 * n * n;
    let rmax = flops / total;
    HplResult {
        params: params.clone(),
        time_s: total,
        flops,
        rmax,
        rmax_per_gpu: rmax / ranks as f64,
        max_gemm_per_gpu: max_gemm_rate,
        update_frac: t_update / total,
        panel_frac: t_panel / total,
        comm_frac: t_comm / total,
    }
}

impl HplResult {
    /// Table 7 rendering.
    pub fn table(&self) -> String {
        let gpu = GpuModel::h100_sxm();
        kv_table(
            "Table 7 — HPL Benchmark Summary (simulated)",
            &[
                ("Matrix size (N)", format!("{}", self.params.n)),
                ("Block size (NB)", format!("{}", self.params.nb)),
                (
                    "Process grid (PxQ)",
                    format!("{} x {}", self.params.p, self.params.q),
                ),
                ("Total processes", format!("{}", self.params.ranks())),
                ("Total GPUs", format!("{}", self.params.ranks())),
                ("HPL version", "sakuraone-sim (HPL-NVIDIA 25.4.0 model)".into()),
                ("Execution time (sec)", format!("{:.2}", self.time_s)),
                ("FLOPS", format!("{:.2} PFLOPS", self.rmax / 1e15)),
                (
                    "FLOPS per GPU",
                    format!("{:.2} TFLOPS", self.rmax_per_gpu / 1e12),
                ),
                (
                    "Max GEMM performance (single GPU)",
                    format!("{:.2} TFLOPS", self.max_gemm_per_gpu / 1e12),
                ),
                ("GPU SM count", format!("{}", gpu.sms)),
                (
                    "GPU peak clock frequency",
                    format!("{} MHz", gpu.peak_clock_mhz),
                ),
                (
                    "Phase split (update/panel/comm)",
                    format!(
                        "{:.0}% / {:.0}% / {:.0}%",
                        100.0 * self.update_frac,
                        100.0 * self.panel_frac,
                        100.0 * self.comm_frac
                    ),
                ),
            ],
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_run_lands_near_published_rmax() {
        let cfg = ClusterConfig::default();
        let res = run_hpl(&cfg, &HplParams::paper());
        let pf = res.rmax / 1e15;
        // Paper: 33.95 PFLOP/s in 389.23 s. Allow 10% modelling error.
        assert!((pf - 33.95).abs() / 33.95 < 0.10, "Rmax {pf} PF");
        assert!(
            (res.time_s - 389.23).abs() / 389.23 < 0.12,
            "time {}",
            res.time_s
        );
    }

    #[test]
    fn per_gpu_rate_matches_table7() {
        let cfg = ClusterConfig::default();
        let res = run_hpl(&cfg, &HplParams::paper());
        let tf = res.rmax_per_gpu / 1e12;
        assert!((tf - 43.31).abs() / 43.31 < 0.10, "{tf} TF/GPU");
        let gm = res.max_gemm_per_gpu / 1e12;
        assert!((gm - 55.34).abs() / 55.34 < 0.05, "{gm} TF max GEMM");
    }

    #[test]
    fn update_phase_dominates() {
        let cfg = ClusterConfig::default();
        let res = run_hpl(&cfg, &HplParams::paper());
        assert!(res.update_frac > 0.6, "update {}", res.update_frac);
    }

    #[test]
    fn smaller_n_lower_efficiency() {
        let cfg = ClusterConfig::default();
        let mut small = HplParams::paper();
        small.n = 262_144;
        small.stride = 4;
        let r_small = run_hpl(&cfg, &small);
        let r_big = run_hpl(&cfg, &HplParams::paper());
        assert!(r_small.rmax < r_big.rmax);
    }

    #[test]
    fn stride_one_close_to_stride_eight() {
        let mut cfg = ClusterConfig::default();
        cfg.apply_override("nodes", "16").unwrap();
        let mut p = HplParams { stride: 1, n: 131_072, nb: 1024, p: 8, q: 16, ..HplParams::paper() };
        let exact = run_hpl(&cfg, &p);
        p.stride = 8;
        let approx = run_hpl(&cfg, &p);
        let rel = (exact.time_s - approx.time_s).abs() / exact.time_s;
        // left-endpoint integration over a decreasing-cost sweep: a few
        // percent bias at this tiny N (128 block steps) is expected
        assert!(rel < 0.05, "stride error {rel}");
    }

    #[test]
    fn grid_mapping() {
        let p = HplParams::paper();
        assert_eq!(grid_coords(&p, 0), (0, 0));
        assert_eq!(grid_coords(&p, 15), (15, 0));
        assert_eq!(grid_coords(&p, 16), (0, 1));
        let cfg = ClusterConfig::default();
        assert_eq!(rank_location(&cfg, 0), (0, 0));
        assert_eq!(rank_location(&cfg, 15), (1, 7));
    }

    #[test]
    #[should_panic(expected = "needs")]
    fn oversized_grid_panics() {
        let mut cfg = ClusterConfig::default();
        cfg.apply_override("nodes", "2").unwrap();
        run_hpl(&cfg, &HplParams::paper());
    }
}
