//! Platform metrics registry: counters and gauges, dumpable as JSON —
//! the observability surface a managed HPC service exposes (paper §3
//! mentions Slurm-integrated performance monitoring).

use std::collections::BTreeMap;

use crate::util::json::Json;

#[derive(Debug, Default, Clone)]
pub struct Metrics {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn inc(&mut self, name: &str) {
        self.add(name, 1);
    }

    pub fn add(&mut self, name: &str, v: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += v;
    }

    pub fn set(&mut self, name: &str, v: f64) {
        self.gauges.insert(name.to_string(), v);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }

    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        for (k, v) in &self.counters {
            m.insert(format!("counter.{k}"), Json::Num(*v as f64));
        }
        for (k, v) in &self.gauges {
            m.insert(format!("gauge.{k}"), Json::Num(*v));
        }
        Json::Obj(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let mut m = Metrics::new();
        m.inc("jobs.submitted");
        m.inc("jobs.submitted");
        m.add("jobs.submitted", 3);
        assert_eq!(m.counter("jobs.submitted"), 5);
        assert_eq!(m.counter("missing"), 0);
    }

    #[test]
    fn gauges_overwrite() {
        let mut m = Metrics::new();
        m.set("hpl.rmax_pflops", 33.9);
        m.set("hpl.rmax_pflops", 34.1);
        assert_eq!(m.gauge("hpl.rmax_pflops"), Some(34.1));
    }

    #[test]
    fn json_dump_prefixes() {
        let mut m = Metrics::new();
        m.inc("a");
        m.set("b", 2.5);
        let j = m.to_json();
        assert!(j.get("counter.a").is_some());
        assert_eq!(j.get("gauge.b").unwrap().as_f64(), Some(2.5));
    }
}
