//! Platform coordination layer: the leader-process API over config,
//! fabric, scheduler, benchmark drivers and the PJRT runtime, plus the
//! metrics registry.

pub mod metrics;
pub mod platform;

pub use metrics::Metrics;
pub use platform::{CgCheck, NumericsCheck, Platform};
