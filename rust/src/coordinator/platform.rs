//! The SAKURAONE platform object: the leader process that owns the
//! cluster configuration, the fabric, the scheduler and the metrics, and
//! exposes the benchmark/workload entry points the CLI and examples call.
//!
//! This is the "managed HPC service" face of the reproduction: users
//! submit named workloads; the platform places them through the Slurm-like
//! scheduler, runs the corresponding simulator (or the real PJRT-backed
//! compute for validation workloads) and records metrics.

use anyhow::Result;

use crate::benchmarks::hpcg::{run_hpcg, HpcgParams, HpcgResult};
use crate::benchmarks::hpl::{run_hpl, HplParams, HplResult};
use crate::benchmarks::hpl_mxp::{run_mxp, MxpParams, MxpResult};
use crate::benchmarks::io500::{run_io500, Io500Params, Io500Result};
use crate::config::ClusterConfig;
use crate::coordinator::metrics::Metrics;
use crate::runtime::Runtime;
use crate::scheduler::{Job, SlurmSim};
use crate::topology::builders::build;
use crate::topology::graph::Fabric;

pub struct Platform {
    pub cfg: ClusterConfig,
    pub fabric: Fabric,
    pub metrics: Metrics,
    runtime: Option<Runtime>,
    next_job_id: u64,
}

impl Platform {
    pub fn new(cfg: ClusterConfig) -> Self {
        debug_assert!(
            cfg.validate().is_ok(),
            "Platform::new: invalid cluster: {:?}",
            cfg.validate()
        );
        let fabric = build(&cfg);
        Self { cfg, fabric, metrics: Metrics::new(), runtime: None, next_job_id: 1 }
    }

    /// Construct the leader for a named registry platform
    /// (`config::spec::PLATFORMS`, e.g. `"sakuraone"`, `"abci3-like"`).
    pub fn from_registry(name: &str) -> Result<Self> {
        let d = crate::config::spec::platform_or_err(name)
            .map_err(anyhow::Error::msg)?;
        Ok(Self::new((d.build)()))
    }

    /// Lazily attach the PJRT runtime (needs `make artifacts`).
    pub fn runtime(&mut self) -> Result<&mut Runtime> {
        if self.runtime.is_none() {
            self.runtime = Some(Runtime::load_default()?);
        }
        Ok(self.runtime.as_mut().unwrap())
    }

    fn job_id(&mut self) -> u64 {
        let id = self.next_job_id;
        self.next_job_id += 1;
        id
    }

    /// Schedule a benchmark as a cluster job (captures queueing behaviour),
    /// then run its simulator. Returns (scheduler wait time, result).
    fn as_scheduled_job(&mut self, name: &str, nodes: usize, est_runtime: f64) -> f64 {
        let mut sim = SlurmSim::new(&self.cfg);
        let id = self.job_id();
        sim.submit(Job::new(id, name, nodes, est_runtime * 1.5, est_runtime));
        let stats = sim.run();
        self.metrics.inc("jobs.completed");
        stats.mean_wait
    }

    pub fn hpl(&mut self, params: &HplParams) -> HplResult {
        let nodes = params.ranks().div_ceil(self.cfg.node.gpus_per_node);
        let r = run_hpl(&self.cfg, params);
        self.as_scheduled_job("hpl", nodes, r.time_s);
        self.metrics.set("hpl.rmax_pflops", r.rmax / 1e15);
        self.metrics.set("hpl.time_s", r.time_s);
        r
    }

    pub fn hpcg(&mut self, params: &HpcgParams) -> HpcgResult {
        let nodes = params.ranks().div_ceil(self.cfg.node.gpus_per_node);
        let r = run_hpcg(&self.cfg, params);
        self.as_scheduled_job("hpcg", nodes, 1800.0);
        self.metrics.set("hpcg.final_gflops", r.final_gflops);
        r
    }

    pub fn mxp(&mut self, params: &MxpParams) -> MxpResult {
        let nodes = params.ranks().div_ceil(self.cfg.node.gpus_per_node);
        let r = run_mxp(&self.cfg, params);
        self.as_scheduled_job("hpl-mxp", nodes, r.total_time_s);
        self.metrics.set("mxp.rmax_pflops", r.rmax / 1e15);
        r
    }

    pub fn io500(&mut self, params: &Io500Params) -> Io500Result {
        let r = run_io500(&self.cfg, params);
        self.as_scheduled_job("io500", params.client_nodes, 2400.0);
        self.metrics.set("io500.total_score", r.total_score);
        r
    }

    /// HPL numerics validation through the AOT artifact: factors a random
    /// diagonally-dominant system on the PJRT runtime and applies HPL's
    /// scaled-residual PASS criterion (threshold 16.0, like Table 9).
    pub fn validate_hpl_numerics(&mut self) -> Result<NumericsCheck> {
        let n = 256usize;
        let mut rng = crate::util::rng::Rng::new(0x48504C);
        let mut a = vec![0f32; n * n];
        for (i, v) in a.iter_mut().enumerate() {
            *v = rng.normal() as f32;
            if i % (n + 1) == 0 {
                *v += n as f32; // diagonal dominance (no-pivot-safe)
            }
        }
        let b: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let rt = self.runtime()?;
        let la = Runtime::lit_f32(&a, &[n, n])?;
        let lb = Runtime::lit_f32(&b, &[n])?;
        let out = rt.execute("hpl_solve_256", &[la, lb])?;
        let rn = Runtime::scalar_f32(&out[1])? as f64;
        let an = Runtime::scalar_f32(&out[2])? as f64;
        let bn = Runtime::scalar_f32(&out[4])? as f64;
        let eps = f32::EPSILON as f64;
        let scaled = rn / (eps * (an + bn) * n as f64);
        self.metrics.set("hpl.validation_residual", scaled);
        Ok(NumericsCheck { scaled_residual: scaled, threshold: 16.0 })
    }

    /// HPL-MxP numerics validation (bf16 LU + IR artifact).
    pub fn validate_mxp_numerics(&mut self) -> Result<NumericsCheck> {
        let n = 256usize;
        let mut rng = crate::util::rng::Rng::new(0x4D5850);
        let mut a = vec![0f32; n * n];
        for (i, v) in a.iter_mut().enumerate() {
            *v = rng.normal() as f32;
            if i % (n + 1) == 0 {
                *v += n as f32;
            }
        }
        let b: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let rt = self.runtime()?;
        let la = Runtime::lit_f32(&a, &[n, n])?;
        let lb = Runtime::lit_f32(&b, &[n])?;
        let out = rt.execute("mxp_solve_256", &[la, lb])?;
        let rn = Runtime::scalar_f32(&out[1])? as f64;
        let an = Runtime::scalar_f32(&out[2])? as f64;
        let bn = Runtime::scalar_f32(&out[4])? as f64;
        let eps = f32::EPSILON as f64;
        let scaled = rn / (eps * (an + bn) * n as f64);
        self.metrics.set("mxp.validation_residual", scaled);
        Ok(NumericsCheck { scaled_residual: scaled, threshold: 16.0 })
    }

    /// HPCG numerics validation: CG on the stencil operator must reduce
    /// the residual by many orders of magnitude.
    pub fn validate_hpcg_numerics(&mut self) -> Result<CgCheck> {
        let g = 24usize;
        let mut rng = crate::util::rng::Rng::new(0x435047);
        let b: Vec<f32> = (0..g * g * g).map(|_| rng.normal() as f32).collect();
        let rt = self.runtime()?;
        let lb = Runtime::lit_f32(&b, &[g, g, g])?;
        let out = rt.execute("cg_24", &[lb])?;
        let rr0 = Runtime::scalar_f32(&out[1])? as f64;
        let rr = Runtime::scalar_f32(&out[2])? as f64;
        self.metrics.set("hpcg.validation_rr_ratio", rr / rr0);
        Ok(CgCheck { rr0, rr_final: rr })
    }
}

#[derive(Debug, Clone)]
pub struct NumericsCheck {
    pub scaled_residual: f64,
    pub threshold: f64,
}

impl NumericsCheck {
    pub fn passed(&self) -> bool {
        self.scaled_residual.is_finite() && self.scaled_residual < self.threshold
    }
}

#[derive(Debug, Clone)]
pub struct CgCheck {
    pub rr0: f64,
    pub rr_final: f64,
}

impl CgCheck {
    pub fn passed(&self) -> bool {
        self.rr_final < 1e-6 * self.rr0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn platform_runs_hpl_and_records_metrics() {
        let mut p = Platform::new(ClusterConfig::default());
        let r = p.hpl(&HplParams::paper());
        assert!(r.rmax > 30e15);
        assert!(p.metrics.gauge("hpl.rmax_pflops").unwrap() > 30.0);
        assert_eq!(p.metrics.counter("jobs.completed"), 1);
    }

    #[test]
    fn platform_constructs_from_the_registry() {
        for d in crate::config::PLATFORMS {
            let p = Platform::from_registry(d.name)
                .unwrap_or_else(|e| panic!("{}: {e}", d.name));
            assert_eq!(p.cfg, (d.build)());
            assert!(p.fabric.hosts().count() > 0, "{}: empty fabric", d.name);
        }
        let err = Platform::from_registry("tsubame").unwrap_err();
        assert!(err.to_string().contains("unknown platform"));
    }

    fn artifacts_built() -> bool {
        crate::runtime::Manifest::default_dir().join("manifest.json").exists()
    }

    #[test]
    fn hpl_numerics_pass_like_table9() {
        if !artifacts_built() {
            return; // `make artifacts` not run in this checkout
        }
        let mut p = Platform::new(ClusterConfig::default());
        let check = p.validate_hpl_numerics().expect("hpl artifact must run");
        assert!(check.passed(), "scaled residual {}", check.scaled_residual);
    }

    #[test]
    fn mxp_numerics_pass() {
        if !artifacts_built() {
            return;
        }
        let mut p = Platform::new(ClusterConfig::default());
        let check = p.validate_mxp_numerics().expect("mxp artifact must run");
        assert!(check.passed(), "scaled residual {}", check.scaled_residual);
    }

    #[test]
    fn hpcg_numerics_converge() {
        if !artifacts_built() {
            return;
        }
        let mut p = Platform::new(ClusterConfig::default());
        let check = p.validate_hpcg_numerics().expect("cg artifact must run");
        assert!(check.passed(), "rr {} -> {}", check.rr0, check.rr_final);
    }
}
