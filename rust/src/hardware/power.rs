//! Power and energy model — the paper's stated future work (§6: "extend
//! this evaluation to include power consumption and performance-per-watt
//! analysis"). Implemented here as a first-class extension feature.
//!
//! Component powers come from vendor specs (H100 SXM 700 W TDP, Xeon
//! 8580+ 350 W TDP, Tomahawk-5 switch ~550 W class, ConnectX-7 ~25 W,
//! DDN NVMe shelf ~2 kW); per-benchmark draw scales idle->TDP with the
//! utilisation each simulator reports. Energy = sum(component power x
//! benchmark wall time); efficiency = Rmax / cluster power — the
//! Green500 metric.

use crate::config::ClusterConfig;

#[derive(Debug, Clone)]
pub struct PowerModel {
    /// GPU draw at idle and at full tensor-pipe load (W).
    pub gpu_idle_w: f64,
    pub gpu_tdp_w: f64,
    /// Per-socket CPU draw (W).
    pub cpu_idle_w: f64,
    pub cpu_tdp_w: f64,
    /// DRAM per node (W), roughly constant.
    pub dram_w: f64,
    /// Per NIC (W).
    pub nic_w: f64,
    /// Per Ethernet switch chassis (W).
    pub switch_w: f64,
    /// Per storage server chassis (W).
    pub storage_server_w: f64,
    /// Facility overhead multiplier (cooling, PSU loss): PUE.
    pub pue: f64,
}

impl PowerModel {
    pub fn sakuraone() -> Self {
        Self {
            gpu_idle_w: 90.0,
            gpu_tdp_w: 700.0,
            cpu_idle_w: 70.0,
            cpu_tdp_w: 350.0,
            dram_w: 60.0,
            nic_w: 25.0,
            switch_w: 550.0,
            storage_server_w: 2_000.0,
            pue: 1.35,
        }
    }

    /// Cluster IT power (W) at a given GPU utilisation in [0, 1] and CPU
    /// utilisation (HPL keeps CPUs mostly feeding, ~30%).
    pub fn cluster_power_w(
        &self,
        cfg: &ClusterConfig,
        gpu_util: f64,
        cpu_util: f64,
    ) -> f64 {
        let nodes = cfg.nodes as f64;
        let gpus = cfg.total_gpus() as f64;
        let gpu = gpus * (self.gpu_idle_w + gpu_util * (self.gpu_tdp_w - self.gpu_idle_w));
        let cpu = nodes
            * cfg.node.cpus_per_node as f64
            * (self.cpu_idle_w + cpu_util * (self.cpu_tdp_w - self.cpu_idle_w));
        let dram = nodes * self.dram_w;
        let nics = nodes
            * (cfg.node.compute_nics + cfg.node.storage_nics + 1) as f64
            * self.nic_w;
        let switches = (cfg.network.pods * cfg.network.leaf_per_pod
            + cfg.network.spines
            + cfg.storage.storage_switches) as f64
            * self.switch_w;
        let storage = cfg.storage.servers as f64 * self.storage_server_w;
        gpu + cpu + dram + nics + switches + storage
    }

    /// Facility power including PUE.
    pub fn facility_power_w(&self, cfg: &ClusterConfig, gpu_util: f64, cpu_util: f64) -> f64 {
        self.cluster_power_w(cfg, gpu_util, cpu_util) * self.pue
    }
}

/// A benchmark's energy/efficiency summary.
#[derive(Debug, Clone)]
pub struct EnergyReport {
    pub name: String,
    pub wall_s: f64,
    pub avg_power_w: f64,
    pub energy_mj: f64,
    /// FLOP/s per watt (Green500 uses GFLOPS/W on HPL).
    pub gflops_per_w: f64,
}

pub fn energy_for(
    model: &PowerModel,
    cfg: &ClusterConfig,
    name: &str,
    wall_s: f64,
    sustained_flops: f64,
    gpu_util: f64,
    cpu_util: f64,
) -> EnergyReport {
    let p = model.cluster_power_w(cfg, gpu_util, cpu_util);
    EnergyReport {
        name: name.to_string(),
        wall_s,
        avg_power_w: p,
        energy_mj: p * wall_s / 1e6,
        gflops_per_w: sustained_flops / 1e9 / p,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (PowerModel, ClusterConfig) {
        (PowerModel::sakuraone(), ClusterConfig::default())
    }

    #[test]
    fn idle_cluster_in_plausible_band() {
        let (m, cfg) = setup();
        let p = m.cluster_power_w(&cfg, 0.0, 0.0);
        // 800 GPUs idle + base: a few hundred kW
        assert!(p > 100e3 && p < 400e3, "{p} W");
    }

    #[test]
    fn full_load_near_nameplate() {
        let (m, cfg) = setup();
        let p = m.cluster_power_w(&cfg, 1.0, 0.5);
        // 800 x 700W = 560 kW GPUs alone; with hosts/fabric ~ 700-800 kW
        assert!(p > 600e3 && p < 900e3, "{p} W");
    }

    #[test]
    fn hpl_efficiency_in_green500_band() {
        // H100 FP64 systems rate ~25-65 GFLOPS/W on Green500; our HPL at
        // 33.95 PF should land in that band.
        let (m, cfg) = setup();
        let rep = energy_for(&m, &cfg, "hpl", 389.23, 33.95e15, 0.85, 0.3);
        assert!(
            rep.gflops_per_w > 25.0 && rep.gflops_per_w < 70.0,
            "{} GF/W",
            rep.gflops_per_w
        );
    }

    #[test]
    fn energy_scales_with_time() {
        let (m, cfg) = setup();
        let a = energy_for(&m, &cfg, "x", 100.0, 1e15, 0.5, 0.3);
        let b = energy_for(&m, &cfg, "x", 200.0, 1e15, 0.5, 0.3);
        assert!((b.energy_mj / a.energy_mj - 2.0).abs() < 1e-9);
    }

    #[test]
    fn pue_multiplies() {
        let (m, cfg) = setup();
        let it = m.cluster_power_w(&cfg, 0.5, 0.3);
        let fac = m.facility_power_w(&cfg, 0.5, 0.3);
        assert!((fac / it - 1.35).abs() < 1e-9);
    }

    #[test]
    fn mxp_more_efficient_than_hpl() {
        // FP8 work per joule dwarfs FP64 work per joule
        let (m, cfg) = setup();
        let hpl = energy_for(&m, &cfg, "hpl", 389.0, 33.95e15, 0.85, 0.3);
        let mxp = energy_for(&m, &cfg, "mxp", 52.0, 339.86e15, 0.9, 0.3);
        assert!(mxp.gflops_per_w > 5.0 * hpl.gflops_per_w);
    }
}
