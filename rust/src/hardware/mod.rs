//! Hardware substrate models: GPU roofline, node inventory, PCIe/NUMA
//! topology (Table 2), and the intra-node NVSwitch fabric.
//!
//! Substitution note (DESIGN.md §2): the paper measured real H100 systems;
//! we model them analytically from public pipe/bandwidth specs so the
//! simulated benchmarks derive their numbers instead of quoting them.

pub mod gpu;
pub mod node;
pub mod nvswitch;
pub mod pcie;
pub mod power;

pub use gpu::{GpuModel, Precision};
pub use node::NodeModel;
pub use nvswitch::NvSwitchFabric;
pub use pcie::{NodePcieTopology, PathClass};
pub use power::{energy_for, EnergyReport, PowerModel};
