//! Analytic GPU performance model (H100 SXM 80GB by default).
//!
//! The simulated benchmarks derive their compute times from first-principles
//! roofline terms — peak pipe rates, HBM bandwidth, and an empirical GEMM
//! efficiency curve — rather than from the paper's reported numbers, so the
//! Table 7/9 results *emerge* from the model.

/// Numeric precision / execution pipe.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Precision {
    /// FP64 through tensor cores (HPL).
    Fp64Tensor,
    /// FP64 vector pipe (HPCG stencil math).
    Fp64Vector,
    Tf32,
    Bf16,
    /// FP8 tensor cores (HPL-MxP 'Sloppy FP8' mode).
    Fp8,
}

#[derive(Debug, Clone)]
pub struct GpuModel {
    pub name: String,
    pub sms: u32,
    pub peak_clock_mhz: f64,
    /// Dense peak rates, FLOP/s.
    pub fp64_tensor_flops: f64,
    pub fp64_vector_flops: f64,
    pub tf32_flops: f64,
    pub bf16_flops: f64,
    pub fp8_flops: f64,
    pub hbm_bytes: f64,
    pub hbm_bw_bytes_per_s: f64,
    /// NVLink4 per-GPU aggregate (one direction).
    pub nvlink_bw_bytes_per_s: f64,
    /// Empirical ceiling on achievable GEMM efficiency (fraction of peak);
    /// large-n DGEMM on H100 sustains ~83% of the FP64-TC peak
    /// (55.34/67 in the paper's own Table 7), FP8 GEMM ~40% of its much
    /// higher peak before becoming dataflow limited.
    pub gemm_max_eff_fp64: f64,
    pub gemm_max_eff_lowp: f64,
    /// Fixed kernel-launch/setup overhead per GEMM call.
    pub kernel_overhead: f64,
}

impl GpuModel {
    pub fn h100_sxm() -> Self {
        Self {
            name: "NVIDIA H100 SXM 80GB".into(),
            sms: 132,
            peak_clock_mhz: 1980.0,
            fp64_tensor_flops: 66.9e12,
            fp64_vector_flops: 33.5e12,
            tf32_flops: 494.7e12,
            bf16_flops: 989.4e12,
            fp8_flops: 1978.9e12,
            hbm_bytes: 80e9,
            hbm_bw_bytes_per_s: 3.35e12,
            nvlink_bw_bytes_per_s: 450e9,
            gemm_max_eff_fp64: 0.827,
            gemm_max_eff_lowp: 0.40,
            kernel_overhead: 5e-6,
        }
    }

    pub fn peak_flops(&self, p: Precision) -> f64 {
        match p {
            Precision::Fp64Tensor => self.fp64_tensor_flops,
            Precision::Fp64Vector => self.fp64_vector_flops,
            Precision::Tf32 => self.tf32_flops,
            Precision::Bf16 => self.bf16_flops,
            Precision::Fp8 => self.fp8_flops,
        }
    }

    fn gemm_max_eff(&self, p: Precision) -> f64 {
        match p {
            Precision::Fp64Tensor | Precision::Fp64Vector => {
                self.gemm_max_eff_fp64
            }
            _ => self.gemm_max_eff_lowp,
        }
    }

    /// Input element size in bytes for a precision's GEMM operands.
    pub fn elem_bytes(p: Precision) -> f64 {
        match p {
            Precision::Fp64Tensor | Precision::Fp64Vector => 8.0,
            Precision::Tf32 => 4.0,
            Precision::Bf16 => 2.0,
            Precision::Fp8 => 1.0,
        }
    }

    /// Wall time for an (m, n, k) GEMM (2mnk flops): a roofline model —
    /// max of the tensor-pipe time (derated by the empirical ceiling) and
    /// the HBM time to stream A, B and read+write C, plus a fixed launch
    /// overhead. Small/skinny GEMMs land on the memory or overhead leg,
    /// large trailing updates on the compute leg — reproducing both the
    /// 55.34 TFLOP/s peak-GEMM row and HPL's panel inefficiency.
    pub fn gemm_time(&self, m: f64, n: f64, k: f64, p: Precision) -> f64 {
        let flops = 2.0 * m * n * k;
        let t_compute = flops / (self.peak_flops(p) * self.gemm_max_eff(p));
        // C is accumulated at >= fp16 width even for fp8 inputs.
        let c_bytes = Self::elem_bytes(p).max(2.0);
        let bytes = (m * k + k * n) * Self::elem_bytes(p) + 2.0 * m * n * c_bytes;
        let t_mem = bytes / self.hbm_bw_bytes_per_s;
        t_compute.max(t_mem) + self.kernel_overhead
    }

    /// Achieved GEMM rate (FLOP/s) for an (m, n, k) product.
    pub fn gemm_flops(&self, m: f64, n: f64, k: f64, p: Precision) -> f64 {
        let flops = 2.0 * m * n * k;
        flops / self.gemm_time(m, n, k, p)
    }

    /// Achieved efficiency (fraction of the pipe peak).
    pub fn gemm_efficiency(&self, m: f64, n: f64, k: f64, p: Precision) -> f64 {
        self.gemm_flops(m, n, k, p) / self.peak_flops(p)
    }

    /// Wall time to stream `bytes` through HBM at `eff` fraction of peak.
    pub fn stream_time(&self, bytes: f64, eff: f64) -> f64 {
        bytes / (self.hbm_bw_bytes_per_s * eff)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn h100_headline_numbers() {
        let g = GpuModel::h100_sxm();
        assert_eq!(g.sms, 132);
        assert_eq!(g.peak_clock_mhz, 1980.0);
        assert!((g.fp64_tensor_flops - 66.9e12).abs() < 1e9);
        assert!((g.fp8_flops / g.fp64_tensor_flops - 29.6).abs() < 0.5);
    }

    #[test]
    fn large_gemm_approaches_paper_max() {
        // Paper Table 7: max single-GPU GEMM 55.34 TFLOP/s.
        let g = GpuModel::h100_sxm();
        let rate = g.gemm_flops(40_000.0, 40_000.0, 1024.0, Precision::Fp64Tensor);
        assert!(
            (rate / 1e12 - 55.34).abs() < 2.0,
            "got {} TFLOP/s",
            rate / 1e12
        );
    }

    #[test]
    fn small_gemm_is_inefficient() {
        let g = GpuModel::h100_sxm();
        let eff = g.gemm_efficiency(128.0, 128.0, 128.0, Precision::Fp64Tensor);
        assert!(eff < 0.02, "eff={eff}");
    }

    #[test]
    fn skinny_gemm_is_memory_bound() {
        let g = GpuModel::h100_sxm();
        // m=n huge, k=1: 2 flops per 10 bytes -> far below compute roof
        let eff = g.gemm_efficiency(20_000.0, 20_000.0, 1.0, Precision::Fp64Tensor);
        assert!(eff < 0.05, "eff={eff}");
    }

    #[test]
    fn efficiency_monotone_in_size() {
        let g = GpuModel::h100_sxm();
        let mut last = 0.0;
        for n in [64.0, 256.0, 1024.0, 4096.0, 16384.0] {
            let e = g.gemm_efficiency(n, n, n, Precision::Fp64Tensor);
            assert!(e > last);
            last = e;
        }
        assert!(last < 1.0);
    }

    #[test]
    fn gemm_time_scales_cubically() {
        let g = GpuModel::h100_sxm();
        let t1 = g.gemm_time(8192.0, 8192.0, 8192.0, Precision::Fp64Tensor);
        let t2 = g.gemm_time(16384.0, 16384.0, 16384.0, Precision::Fp64Tensor);
        let ratio = t2 / t1;
        assert!(ratio > 6.0 && ratio < 8.5, "ratio={ratio}");
    }

    #[test]
    fn fp8_pipe_much_faster() {
        let g = GpuModel::h100_sxm();
        let t64 = g.gemm_time(16384.0, 16384.0, 4096.0, Precision::Fp64Tensor);
        let t8 = g.gemm_time(16384.0, 16384.0, 4096.0, Precision::Fp8);
        assert!(t64 / t8 > 8.0, "speedup {}", t64 / t8);
    }

    #[test]
    fn stream_time_basic() {
        let g = GpuModel::h100_sxm();
        let t = g.stream_time(3.35e12, 1.0);
        assert!((t - 1.0).abs() < 1e-9);
    }
}
