//! PCIe/NUMA topology inside a compute node — reproduces the paper's
//! Table 2 classification of NIC usage derived from `nvidia-smi topo -mp`.
//!
//! The SYS-821GE-TNHR routes each compute NIC through the PCIe switch of
//! its companion GPU (NODE paths), the two storage NICs through longer
//! multi-bridge paths (PXB), and the management NIC across the NUMA
//! boundary (SYS).

use crate::util::table::Table;

/// PCIe path classification, as printed by `nvidia-smi topo -mp`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum PathClass {
    /// Same PCIe switch (GPU-companion slot): fastest host path.
    Pix,
    /// Same NUMA node, through the PCIe host bridge.
    Node,
    /// Multiple PCIe bridges, same socket.
    Pxb,
    /// Crosses the inter-socket (NUMA) boundary.
    Sys,
}

impl PathClass {
    pub fn label(&self) -> &'static str {
        match self {
            PathClass::Pix => "PIX",
            PathClass::Node => "NODE",
            PathClass::Pxb => "PXB",
            PathClass::Sys => "SYS",
        }
    }

    /// Relative latency multiplier for host<->NIC DMA setup; NODE-local
    /// paths are the baseline RoCEv2 doorbell/completion cost.
    pub fn latency_factor(&self) -> f64 {
        match self {
            PathClass::Pix => 0.9,
            PathClass::Node => 1.0,
            PathClass::Pxb => 1.35,
            PathClass::Sys => 1.9,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NicRole {
    Compute,
    Storage,
    Management,
}

#[derive(Debug, Clone)]
pub struct Nic {
    pub index: usize,
    pub device: String,
    pub role: NicRole,
    /// GPU whose PCIe domain hosts this NIC (compute NICs only).
    pub companion_gpu: Option<usize>,
    pub gbps: f64,
}

/// The node-internal device topology (8 GPUs + 11 logical NICs).
#[derive(Debug, Clone)]
pub struct NodePcieTopology {
    pub gpus: usize,
    pub nics: Vec<Nic>,
}

impl NodePcieTopology {
    /// The SAKURAONE node layout (paper Table 2).
    pub fn sakuraone() -> Self {
        let mut nics = Vec::new();
        for g in 0..8 {
            nics.push(Nic {
                index: g,
                device: format!("mlx5_{g}"),
                role: NicRole::Compute,
                companion_gpu: Some(g),
                gbps: 400.0,
            });
        }
        nics.push(Nic {
            index: 8,
            device: "mlx5_8".into(),
            role: NicRole::Storage,
            companion_gpu: None,
            gbps: 400.0,
        });
        nics.push(Nic {
            index: 9,
            device: "mlx5_11".into(),
            role: NicRole::Management,
            companion_gpu: None,
            gbps: 4.0,
        });
        nics.push(Nic {
            index: 10,
            device: "mlx5_bond_0".into(),
            role: NicRole::Storage,
            companion_gpu: None,
            gbps: 400.0,
        });
        Self { gpus: 8, nics }
    }

    /// Classify the PCIe path between a NIC and a GPU, mirroring the
    /// `nvidia-smi topo -mp` output the paper analysed.
    pub fn classify(&self, nic: &Nic, gpu: usize) -> PathClass {
        match nic.role {
            NicRole::Compute => {
                if nic.companion_gpu == Some(gpu) {
                    PathClass::Node
                } else if nic.companion_gpu.map(|g| g / 4) == Some(gpu / 4) {
                    // same socket, different PCIe domain
                    PathClass::Pxb
                } else {
                    PathClass::Sys
                }
            }
            NicRole::Storage => PathClass::Pxb,
            NicRole::Management => PathClass::Sys,
        }
    }

    /// Table 2 equivalent: one row per NIC with primary usage and the
    /// connectivity class of its *best* GPU path.
    pub fn usage_table(&self) -> Table {
        let mut t = Table::new(
            "Table 2 — NIC usage and GPU connectivity",
            &["NIC", "Device", "Primary Usage", "GPU Connectivity"],
        );
        for nic in &self.nics {
            let best = (0..self.gpus)
                .map(|g| self.classify(nic, g))
                .min()
                .unwrap();
            let usage = match nic.role {
                NicRole::Compute => "High-speed inter-node communication",
                NicRole::Storage => "Storage network",
                NicRole::Management => "Management network (e.g., SSH)",
            };
            let conn = match nic.role {
                NicRole::Compute => format!(
                    "{} (via GPU{} PCIe domain)",
                    best.label(),
                    nic.companion_gpu.unwrap()
                ),
                _ => best.label().to_string(),
            };
            t.row(&[
                format!("NIC{}", nic.index),
                nic.device.clone(),
                usage.to_string(),
                conn,
            ]);
        }
        t
    }

    /// Full `nvidia-smi topo -mp`-style matrix (NIC x GPU).
    pub fn matrix(&self) -> Table {
        let mut headers: Vec<String> = vec!["".into()];
        headers.extend((0..self.gpus).map(|g| format!("GPU{g}")));
        let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
        let mut t = Table::new("NIC/GPU PCIe path matrix", &hdr_refs);
        for nic in &self.nics {
            let mut row = vec![nic.device.clone()];
            for g in 0..self.gpus {
                row.push(self.classify(nic, g).label().to_string());
            }
            t.row(&row);
        }
        t
    }

    pub fn compute_nic_for_gpu(&self, gpu: usize) -> Option<&Nic> {
        self.nics
            .iter()
            .find(|n| n.role == NicRole::Compute && n.companion_gpu == Some(gpu))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sakuraone_has_11_nics() {
        let t = NodePcieTopology::sakuraone();
        assert_eq!(t.nics.len(), 11);
        assert_eq!(
            t.nics.iter().filter(|n| n.role == NicRole::Compute).count(),
            8
        );
        assert_eq!(
            t.nics.iter().filter(|n| n.role == NicRole::Storage).count(),
            2
        );
    }

    #[test]
    fn compute_nics_are_node_local_to_their_gpu() {
        let t = NodePcieTopology::sakuraone();
        for g in 0..8 {
            let nic = t.compute_nic_for_gpu(g).unwrap();
            assert_eq!(t.classify(nic, g), PathClass::Node);
        }
    }

    #[test]
    fn cross_socket_is_sys() {
        let t = NodePcieTopology::sakuraone();
        let nic0 = t.compute_nic_for_gpu(0).unwrap();
        assert_eq!(t.classify(nic0, 7), PathClass::Sys);
        assert_eq!(t.classify(nic0, 2), PathClass::Pxb);
    }

    #[test]
    fn storage_nics_are_pxb() {
        let t = NodePcieTopology::sakuraone();
        for nic in t.nics.iter().filter(|n| n.role == NicRole::Storage) {
            for g in 0..8 {
                assert_eq!(t.classify(nic, g), PathClass::Pxb);
            }
        }
    }

    #[test]
    fn management_nic_is_sys_and_slow() {
        let t = NodePcieTopology::sakuraone();
        let m = t
            .nics
            .iter()
            .find(|n| n.role == NicRole::Management)
            .unwrap();
        assert_eq!(m.device, "mlx5_11");
        assert!(m.gbps < 10.0);
        assert_eq!(t.classify(m, 0), PathClass::Sys);
    }

    #[test]
    fn usage_table_matches_paper_rows() {
        let t = NodePcieTopology::sakuraone();
        let s = t.usage_table().render();
        assert!(s.contains("mlx5_bond_0"));
        assert!(s.contains("NODE (via GPU0 PCIe domain)"));
        assert!(s.contains("Management network"));
    }

    #[test]
    fn latency_factors_ordered() {
        assert!(PathClass::Node.latency_factor() < PathClass::Pxb.latency_factor());
        assert!(PathClass::Pxb.latency_factor() < PathClass::Sys.latency_factor());
    }
}
