//! Intra-node GPU fabric model: NVLink4 + NVSwitch (SXM baseboard).
//!
//! Used by the rail-aligned hierarchical collectives: the intra-node
//! reduce-scatter/all-gather phases ride this fabric while the inter-node
//! phase rides the Ethernet rails.

use super::gpu::GpuModel;

#[derive(Debug, Clone)]
pub struct NvSwitchFabric {
    pub gpus: usize,
    /// Per-GPU one-direction NVLink bandwidth (bytes/s).
    pub per_gpu_bw: f64,
    /// Per-hop latency through NVSwitch.
    pub latency: f64,
    /// Achievable fraction of link rate (NCCL protocol efficiency).
    pub efficiency: f64,
}

impl NvSwitchFabric {
    pub fn h100_baseboard(gpu: &GpuModel, gpus: usize) -> Self {
        Self {
            gpus,
            per_gpu_bw: gpu.nvlink_bw_bytes_per_s,
            latency: 2.0e-6,
            efficiency: 0.80,
        }
    }

    fn effective_bw(&self) -> f64 {
        self.per_gpu_bw * self.efficiency
    }

    /// Ring reduce-scatter of `bytes` per GPU across the node:
    /// (g-1)/g of the buffer crosses each GPU's links.
    pub fn reduce_scatter_time(&self, bytes: f64) -> f64 {
        if self.gpus <= 1 {
            return 0.0;
        }
        let g = self.gpus as f64;
        self.latency * (g - 1.0) + bytes * (g - 1.0) / g / self.effective_bw()
    }

    /// Ring all-gather — symmetric cost to reduce-scatter.
    pub fn all_gather_time(&self, bytes: f64) -> f64 {
        self.reduce_scatter_time(bytes)
    }

    /// Full intra-node all-reduce (RS + AG).
    pub fn all_reduce_time(&self, bytes: f64) -> f64 {
        self.reduce_scatter_time(bytes) + self.all_gather_time(bytes)
    }

    /// One point-to-point GPU→GPU copy through NVSwitch (the intra-node
    /// hop the collectives layer charges for same-node exchanges and
    /// cross-rail relays).
    pub fn p2p_time(&self, bytes: f64) -> f64 {
        self.latency + bytes / (self.per_gpu_bw * self.efficiency)
    }

    /// Broadcast via NVSwitch multicast-ish pipeline.
    pub fn broadcast_time(&self, bytes: f64) -> f64 {
        if self.gpus <= 1 {
            return 0.0;
        }
        self.latency + bytes / self.effective_bw()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::gpu::GpuModel;

    fn fabric() -> NvSwitchFabric {
        NvSwitchFabric::h100_baseboard(&GpuModel::h100_sxm(), 8)
    }

    #[test]
    fn allreduce_1gib_sub_10ms() {
        let t = fabric().all_reduce_time(1e9);
        assert!(t > 1e-3 && t < 10e-3, "t={t}");
    }

    #[test]
    fn single_gpu_is_free() {
        let mut f = fabric();
        f.gpus = 1;
        assert_eq!(f.all_reduce_time(1e9), 0.0);
    }

    #[test]
    fn latency_floor_for_tiny_messages() {
        let f = fabric();
        let t = f.all_reduce_time(8.0);
        assert!(t >= 2.0 * f.latency * 7.0, "t={t}");
    }

    #[test]
    fn bandwidth_term_dominates_large() {
        let f = fabric();
        let t1 = f.all_reduce_time(1e9);
        let t2 = f.all_reduce_time(2e9);
        assert!((t2 / t1 - 2.0).abs() < 0.05);
    }

    #[test]
    fn broadcast_cheaper_than_allreduce() {
        let f = fabric();
        assert!(f.broadcast_time(1e9) < f.all_reduce_time(1e9));
    }

    #[test]
    fn p2p_is_latency_plus_serialization() {
        let f = fabric();
        assert!((f.p2p_time(0.0) - f.latency).abs() < 1e-15);
        let t = f.p2p_time(1e9);
        assert!(t > f.latency && t < f.all_reduce_time(1e9));
    }
}
