//! Whole-compute-node model: CPUs, DRAM, 8 GPUs, NVSwitch fabric, NICs.

use super::gpu::{GpuModel, Precision};
use super::nvswitch::NvSwitchFabric;
use super::pcie::NodePcieTopology;
use crate::config::NodeConfig;

#[derive(Debug, Clone)]
pub struct NodeModel {
    pub config: NodeConfig,
    pub gpu: GpuModel,
    pub fabric: NvSwitchFabric,
    pub pcie: NodePcieTopology,
}

impl NodeModel {
    pub fn sakuraone(config: &NodeConfig) -> Self {
        let gpu = GpuModel::h100_sxm();
        let fabric = NvSwitchFabric::h100_baseboard(&gpu, config.gpus_per_node);
        Self {
            config: config.clone(),
            gpu,
            fabric,
            pcie: NodePcieTopology::sakuraone(),
        }
    }

    pub fn cores(&self) -> usize {
        self.config.cpus_per_node * self.config.cores_per_cpu
    }

    /// Node peak for a precision (all GPUs).
    pub fn peak_flops(&self, p: Precision) -> f64 {
        self.gpu.peak_flops(p) * self.config.gpus_per_node as f64
    }

    /// Aggregate HBM bandwidth.
    pub fn hbm_bw(&self) -> f64 {
        self.gpu.hbm_bw_bytes_per_s * self.config.gpus_per_node as f64
    }

    /// Aggregate compute-fabric injection bandwidth (bytes/s one direction).
    pub fn injection_bw(&self) -> f64 {
        self.config.compute_nics as f64 * self.config.compute_nic_gbps * 1e9
            / 8.0
    }

    /// Local NVMe scratch capacity.
    pub fn scratch_bytes(&self) -> f64 {
        self.config.nvme_drives as f64 * self.config.nvme_bytes_each
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::NodeConfig;

    fn node() -> NodeModel {
        NodeModel::sakuraone(&NodeConfig::default())
    }

    #[test]
    fn table1_inventory() {
        let n = node();
        assert_eq!(n.cores(), 120);
        assert_eq!(n.config.gpus_per_node, 8);
        assert!((n.scratch_bytes() - 30.72e12).abs() < 1e9);
    }

    #[test]
    fn node_fp64_peak_over_half_pflop() {
        let n = node();
        let p = n.peak_flops(Precision::Fp64Tensor);
        assert!(p > 0.5e15 && p < 0.6e15, "{p}");
    }

    #[test]
    fn injection_is_8x400gbe() {
        let n = node();
        assert!((n.injection_bw() - 400e9).abs() < 1.0); // 3200 Gb/s = 400 GB/s
    }

    #[test]
    fn hbm_aggregate() {
        let n = node();
        assert!((n.hbm_bw() - 8.0 * 3.35e12).abs() < 1e9);
    }
}
