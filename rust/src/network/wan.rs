//! Hierarchical two-level flow solving for multi-site WANs.
//!
//! The solver composes the existing incremental max-min [`FlowSim`] with a
//! small site-level water-filler:
//!
//! - **intra-site** flows (`site_src == site_dst`) are delegated *verbatim*
//!   to that site's own `FlowSim` — same batch, same order, same solver
//!   mode — so a one-site WAN (or any batch with zero inter-site flows)
//!   produces bit-identical per-site reports to the flat single-site path.
//!   `tests/proptest_wan.rs` pins this equivalence;
//! - **inter-site** flows are aggregates between site borders: each rides
//!   its fixed deterministic shortest-hop [`WanGraph`] route and shares
//!   WAN-link capacity max-min fairly through a progressive-filling event
//!   loop (bottleneck links frozen in link-id order, epochs at flow
//!   start/finish events), mirroring the single-site solver's semantics at
//!   site granularity.
//!
//! On top sits [`cross_site_allreduce`]: cross-site data-parallel
//! all-reduce as the max of per-site hierarchical all-reduces (phase 1)
//! plus a ring over site leaders — `2(S-1)` WAN steps of `bytes/S`
//! (phase 2) — the Alps/Apertus-style schedule the ROADMAP scale-out item
//! asks for. Tightening any WAN link (less bandwidth or availability)
//! never makes phase 2 faster; `tests/proptest_wan.rs` pins that
//! monotonicity too.

use std::collections::BTreeMap;

use crate::collectives::CollectiveEngine;
use crate::config::ClusterConfig;
use crate::network::roce::RoceParams;
use crate::network::sim::{Flow, FlowResult, FlowSim, SimReport};
use crate::topology::graph::{DeviceId, Fabric};
use crate::topology::wan::WanGraph;

/// Relative retire tolerance, mirroring the single-site solver's
/// scale-aware epsilons: a flow finishes when its residual drops below
/// this fraction of its original size.
const RETIRE_REL: f64 = 1e-12;

/// One flow of a WAN batch. When `site_src == site_dst` the flow is
/// intra-site and `src`/`dst`/`label` address devices of that site's
/// fabric (delegated verbatim to its `FlowSim`); otherwise the flow is an
/// inter-site aggregate between site borders and the device fields are
/// ignored.
#[derive(Debug, Clone)]
pub struct WanFlow {
    pub site_src: usize,
    pub site_dst: usize,
    pub src: DeviceId,
    pub dst: DeviceId,
    pub bytes: f64,
    pub start: f64,
    pub label: u64,
}

/// Result of a hierarchical run.
#[derive(Debug, Clone, Default)]
pub struct HierReport {
    /// Per-site `FlowSim` reports over each site's intra-site sub-batch
    /// (input order preserved within a site), one per site.
    pub site_reports: Vec<SimReport>,
    /// Per-flow results in input order — intra-site entries are copied
    /// bitwise from their site report, inter-site entries come from the
    /// WAN water-filler (`hops` counts WAN hops, `latency` sums one-way
    /// WAN latencies).
    pub results: Vec<FlowResult>,
    /// Completion time of the whole batch (max over sites and WAN tier).
    pub makespan: f64,
    /// Peak utilisation (0..1) per directed WAN-graph link id, sparse.
    pub peak_wan_util: BTreeMap<usize, f64>,
}

impl HierReport {
    pub fn max_wan_util(&self) -> f64 {
        self.peak_wan_util.values().cloned().fold(0.0, f64::max)
    }
}

/// The two-level solver: one [`FlowSim`] per site + the WAN water-filler.
/// Reusable across `run` calls (per-site route caches persist).
pub struct WanSim<'f> {
    pub graph: &'f WanGraph,
    site_sims: Vec<FlowSim<'f>>,
}

impl<'f> WanSim<'f> {
    /// `sites` must be the `WanSpec::build_sites()` output (declaration
    /// order); every site runs the same incremental solver mode and
    /// [`RoceParams`] the flat path uses.
    pub fn new(
        graph: &'f WanGraph,
        sites: &'f [(ClusterConfig, Fabric)],
        roce: RoceParams,
    ) -> Self {
        assert_eq!(graph.n_sites, sites.len(), "graph/site count mismatch");
        Self {
            graph,
            site_sims: sites
                .iter()
                .map(|(_, fabric)| FlowSim::new(fabric, roce.clone()))
                .collect(),
        }
    }

    /// Solve a batch hierarchically. Panics if an inter-site flow is
    /// unroutable (a validated `WanSpec` is always connected).
    pub fn run(&mut self, flows: &[WanFlow]) -> HierReport {
        let n_sites = self.site_sims.len();
        // Split the batch: per-site intra sub-batches (order preserved)
        // and the inter-site aggregate list, remembering input positions.
        let mut site_flows: Vec<Vec<Flow>> = vec![Vec::new(); n_sites];
        let mut site_slots: Vec<Vec<usize>> = vec![Vec::new(); n_sites];
        let mut inter = Vec::new();
        let mut inter_slots = Vec::new();
        for (i, f) in flows.iter().enumerate() {
            assert!(
                f.site_src < n_sites && f.site_dst < n_sites,
                "flow {i}: site index out of range"
            );
            if f.site_src == f.site_dst {
                site_flows[f.site_src].push(Flow {
                    src: f.src,
                    dst: f.dst,
                    bytes: f.bytes,
                    start: f.start,
                    label: f.label,
                });
                site_slots[f.site_src].push(i);
            } else {
                let route = self
                    .graph
                    .route(f.site_src, f.site_dst)
                    .expect("inter-site flow on a disconnected WAN");
                inter.push(InterFlow { route, bytes: f.bytes, start: f.start });
                inter_slots.push(i);
            }
        }

        let mut report = HierReport {
            results: vec![
                FlowResult { finish: 0.0, latency: 0.0, avg_rate: 0.0, hops: 0 };
                flows.len()
            ],
            ..Default::default()
        };

        // Per-site flat solves, verbatim delegation.
        for (s, sim) in self.site_sims.iter_mut().enumerate() {
            let sub = sim.run(&site_flows[s]);
            for (k, &slot) in site_slots[s].iter().enumerate() {
                report.results[slot] = sub.results[k].clone();
            }
            report.makespan = report.makespan.max(sub.makespan);
            report.site_reports.push(sub);
        }

        // WAN tier.
        let (inter_results, wan_makespan, peaks) = solve_inter(self.graph, &inter);
        for (k, &slot) in inter_slots.iter().enumerate() {
            report.results[slot] = inter_results[k].clone();
        }
        report.makespan = report.makespan.max(wan_makespan);
        report.peak_wan_util = peaks;
        report
    }
}

struct InterFlow {
    route: Vec<usize>,
    bytes: f64,
    start: f64,
}

/// Deterministic max-min water-fill of inter-site aggregates on their
/// fixed WAN routes. Epochs at start/finish events; within an epoch,
/// progressive filling freezes the most-contended link (ties broken by
/// link id) and fixes its flows' rates, exactly as the single-site
/// reference solver does per component.
fn solve_inter(
    graph: &WanGraph,
    flows: &[InterFlow],
) -> (Vec<FlowResult>, f64, BTreeMap<usize, f64>) {
    let n = flows.len();
    let mut results =
        vec![FlowResult { finish: 0.0, latency: 0.0, avg_rate: 0.0, hops: 0 }; n];
    let mut peaks: BTreeMap<usize, f64> = BTreeMap::new();
    if n == 0 {
        return (results, 0.0, peaks);
    }

    // 0 = pending, 1 = active, 2 = done — slot order is the tie-break.
    let mut state = vec![0u8; n];
    let mut remaining: Vec<f64> = flows.iter().map(|f| f.bytes).collect();
    let mut rate = vec![0.0f64; n];
    let mut done = 0usize;

    // Degenerate flows complete instantly, matching FlowSim's convention.
    for (i, f) in flows.iter().enumerate() {
        if f.bytes <= 0.0 {
            results[i] = FlowResult {
                finish: f.start,
                latency: 0.0,
                avg_rate: f64::INFINITY,
                hops: 0,
            };
            state[i] = 2;
            done += 1;
        }
    }

    let n_links = graph.links.len();
    let mut t = f64::INFINITY;
    for (i, f) in flows.iter().enumerate() {
        if state[i] == 0 {
            t = t.min(f.start);
        }
    }

    let mut makespan = results
        .iter()
        .zip(&state)
        .filter(|(_, &s)| s == 2)
        .map(|(r, _)| r.finish)
        .fold(0.0f64, f64::max);

    while done < n {
        // Admit every pending flow whose start has arrived.
        for (i, f) in flows.iter().enumerate() {
            if state[i] == 0 && f.start <= t {
                state[i] = 1;
            }
        }

        // Progressive filling over the active set.
        let mut residual: Vec<f64> = graph.links.iter().map(|l| l.bandwidth).collect();
        let mut count = vec![0u32; n_links];
        let mut frozen = vec![false; n];
        let mut unfrozen = 0usize;
        for (i, f) in flows.iter().enumerate() {
            if state[i] == 1 {
                unfrozen += 1;
                for &l in &f.route {
                    count[l] += 1;
                }
            } else {
                frozen[i] = true;
            }
        }
        while unfrozen > 0 {
            // Bottleneck: smallest fair share, smallest link id on ties.
            let mut best: Option<(f64, usize)> = None;
            for l in 0..n_links {
                if count[l] == 0 {
                    continue;
                }
                let share = residual[l] / count[l] as f64;
                if best.map_or(true, |(s, _)| share < s) {
                    best = Some((share, l));
                }
            }
            let (share, l_star) = best.expect("active flows always cross a link");
            for (i, f) in flows.iter().enumerate() {
                if frozen[i] || !f.route.contains(&l_star) {
                    continue;
                }
                rate[i] = share;
                frozen[i] = true;
                unfrozen -= 1;
                for &l in &f.route {
                    residual[l] = (residual[l] - share).max(0.0);
                    count[l] -= 1;
                }
            }
        }

        // Record epoch link loads into the peaks.
        let mut load = vec![0.0f64; n_links];
        for (i, f) in flows.iter().enumerate() {
            if state[i] == 1 {
                for &l in &f.route {
                    load[l] += rate[i];
                }
            }
        }
        for (l, &ld) in load.iter().enumerate() {
            if ld > 0.0 {
                let util = ld / graph.links[l].bandwidth;
                let p = peaks.entry(l).or_insert(0.0);
                *p = p.max(util);
            }
        }

        // Next event: earliest finish or earliest pending start.
        let mut t_next = f64::INFINITY;
        for (i, f) in flows.iter().enumerate() {
            match state[i] {
                1 => t_next = t_next.min(t + remaining[i] / rate[i]),
                0 => t_next = t_next.min(f.start),
                _ => {}
            }
        }
        assert!(t_next.is_finite() && t_next >= t, "WAN solver must advance");
        let dt = t_next - t;

        // Advance and retire.
        t = t_next;
        for (i, f) in flows.iter().enumerate() {
            if state[i] != 1 {
                continue;
            }
            remaining[i] -= rate[i] * dt;
            if remaining[i] <= f.bytes * RETIRE_REL {
                let latency = graph.path_latency(&f.route);
                results[i] = FlowResult {
                    finish: t + latency,
                    latency,
                    avg_rate: f.bytes / (t - f.start),
                    hops: f.route.len(),
                };
                makespan = makespan.max(results[i].finish);
                state[i] = 2;
                done += 1;
            }
        }
    }
    (results, makespan, peaks)
}

/// Timing decomposition of a cross-site data-parallel all-reduce.
#[derive(Debug, Clone, Default)]
pub struct CrossSiteTime {
    /// `intra_s + wan_s`.
    pub total: f64,
    /// Phase 1: max over sites of the per-site hierarchical all-reduce.
    pub intra_s: f64,
    /// Phase 2: ring over site leaders, `2(S-1)` WAN steps of `bytes/S`.
    pub wan_s: f64,
    /// Ethernet flow-transfers simulated across both phases.
    pub flows: usize,
    /// Peak intra-site fabric utilisation across sites (0..1).
    pub max_util: f64,
    /// Peak WAN-link utilisation during phase 2 (0..1; 0 when S == 1).
    pub wan_util: f64,
}

/// Cross-site DP all-reduce riding the WAN tier: each site first reduces
/// `bytes` over its own `nodes_per_site` nodes with the existing
/// [`CollectiveEngine`]; the site leaders then ring-all-reduce the result
/// over the WAN graph. A one-site WAN degenerates to exactly the
/// single-site collective (`wan_s == 0`).
pub fn cross_site_allreduce(
    sites: &[(ClusterConfig, Fabric)],
    graph: &WanGraph,
    nodes_per_site: usize,
    bytes: f64,
) -> CrossSiteTime {
    assert_eq!(graph.n_sites, sites.len(), "graph/site count mismatch");
    let s_count = sites.len();
    let mut out = CrossSiteTime::default();
    if s_count == 0 || bytes <= 0.0 {
        return out;
    }

    // Phase 1: per-site reductions run concurrently; the slowest gates.
    for (cfg, fabric) in sites {
        let engine = CollectiveEngine::new(fabric, cfg);
        let nodes: Vec<usize> = (0..nodes_per_site.min(cfg.nodes)).collect();
        let ct = engine.hierarchical_allreduce(&nodes, bytes);
        out.intra_s = out.intra_s.max(ct.total);
        out.flows += ct.flows;
        out.max_util = out.max_util.max(ct.max_util);
    }

    // Phase 2: leader ring in site-index order. Every step moves
    // bytes/S on each ring edge simultaneously; by the ring schedule
    // there are 2(S-1) such steps (reduce-scatter + all-gather).
    if s_count > 1 {
        let step_flows: Vec<InterFlow> = (0..s_count)
            .map(|i| InterFlow {
                route: graph
                    .route(i, (i + 1) % s_count)
                    .expect("validated WANs are connected"),
                bytes: bytes / s_count as f64,
                start: 0.0,
            })
            .collect();
        let (_, step_time, peaks) = solve_inter(graph, &step_flows);
        let steps = 2 * (s_count - 1);
        out.wan_s = step_time * steps as f64;
        out.flows += steps * s_count;
        out.wan_util = peaks.values().cloned().fold(0.0, f64::max);
    }

    out.total = out.intra_s + out.wan_s;
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::wan::{wan_preset, WanSpec};
    use crate::util::json::Json;

    fn two_site_spec(gbps: f64, availability: f64) -> WanSpec {
        WanSpec::from_json(
            &Json::parse(&format!(
                r#"{{"schema": 1, "name": "t",
                    "sites": [{{"name": "a", "cluster": {{"nodes": 4, "network": {{"pods": 1, "nodes_per_pod": 4}}}}}},
                              {{"name": "b", "cluster": {{"nodes": 4, "network": {{"pods": 1, "nodes_per_pod": 4}}}}}}],
                    "links": [{{"a": "a", "b": "b", "gbps": {gbps}, "rtt_ms": 10, "availability": {availability}}}]}}"#
            ))
            .unwrap(),
        )
        .unwrap()
    }

    #[test]
    fn inter_site_flows_share_the_wan_link_max_min() {
        let spec = two_site_spec(80.0, 1.0); // 10 GB/s payload
        let sites = spec.build_sites();
        let graph = spec.graph();
        let mut sim = WanSim::new(&graph, &sites, RoceParams::ideal());
        let h0 = sites[0].1.host(0, 0).unwrap();
        let mk = |bytes: f64, start: f64| WanFlow {
            site_src: 0,
            site_dst: 1,
            src: h0,
            dst: h0,
            bytes,
            start,
            label: 0,
        };
        // Two equal concurrent flows halve the 10 GB/s wave.
        let r = sim.run(&[mk(10e9, 0.0), mk(10e9, 0.0)]);
        let lat = 5e-3;
        assert!((r.results[0].finish - (2.0 + lat)).abs() < 1e-6, "{r:?}");
        assert!((r.results[1].finish - (2.0 + lat)).abs() < 1e-6);
        assert_eq!(r.results[0].hops, 1);
        assert!((r.results[0].latency - lat).abs() < 1e-12);
        assert!((r.max_wan_util() - 1.0).abs() < 1e-9);
        // A lone flow gets the full wave.
        let r = sim.run(&[mk(10e9, 0.0)]);
        assert!((r.results[0].finish - (1.0 + lat)).abs() < 1e-6, "{r:?}");
        // Zero-byte flows complete instantly, matching FlowSim.
        let r = sim.run(&[mk(0.0, 3.0)]);
        assert_eq!(r.results[0].finish, 3.0);
        assert!(r.results[0].avg_rate.is_infinite());
        assert_eq!(r.results[0].hops, 0);
    }

    #[test]
    fn staggered_starts_water_fill_in_epochs() {
        let spec = two_site_spec(80.0, 1.0); // 10 GB/s
        let sites = spec.build_sites();
        let graph = spec.graph();
        let mut sim = WanSim::new(&graph, &sites, RoceParams::ideal());
        let h0 = sites[0].1.host(0, 0).unwrap();
        let mk = |bytes: f64, start: f64| WanFlow {
            site_src: 0,
            site_dst: 1,
            src: h0,
            dst: h0,
            bytes,
            start,
            label: 0,
        };
        // Flow A: 20 GB at t=0. Flow B: 5 GB at t=1. A runs alone for 1 s
        // (10 GB done), shares for 1 s (5 GB more; B finishes its 5 GB),
        // then runs alone again: 5 GB left -> 0.5 s. A ends at 2.5 s.
        let r = sim.run(&[mk(20e9, 0.0), mk(5e9, 1.0)]);
        let lat = 5e-3;
        assert!((r.results[1].finish - (2.0 + lat)).abs() < 1e-6, "{r:?}");
        assert!((r.results[0].finish - (2.5 + lat)).abs() < 1e-6, "{r:?}");
        assert!((r.makespan - (2.5 + lat)).abs() < 1e-6);
    }

    #[test]
    fn availability_derates_wan_capacity() {
        let full = two_site_spec(80.0, 1.0);
        let derated = two_site_spec(80.0, 0.5);
        let t_full = {
            let sites = full.build_sites();
            cross_site_allreduce(&sites, &full.graph(), 2, 1e9).wan_s
        };
        let t_derated = {
            let sites = derated.build_sites();
            cross_site_allreduce(&sites, &derated.graph(), 2, 1e9).wan_s
        };
        assert!(
            t_derated > t_full * 1.5,
            "half availability ~doubles WAN time: {t_derated} vs {t_full}"
        );
    }

    #[test]
    fn one_site_cross_allreduce_is_the_flat_collective() {
        let spec = WanSpec::from_json(
            &Json::parse(
                r#"{"schema": 1, "name": "solo",
                    "sites": [{"name": "only", "cluster": "sakuraone-halfscale"}]}"#,
            )
            .unwrap(),
        )
        .unwrap();
        let sites = spec.build_sites();
        let graph = spec.graph();
        let x = cross_site_allreduce(&sites, &graph, 8, 256e6);
        assert_eq!(x.wan_s, 0.0);
        assert_eq!(x.wan_util, 0.0);
        let engine = CollectiveEngine::new(&sites[0].1, &sites[0].0);
        let nodes: Vec<usize> = (0..8).collect();
        let flat = engine.hierarchical_allreduce(&nodes, 256e6);
        assert_eq!(x.total.to_bits(), flat.total.to_bits());
        assert_eq!(x.flows, flat.flows);
    }

    #[test]
    fn four_site_ring_runs_end_to_end() {
        let spec = (wan_preset("sakuraone-4site-ring").unwrap().build)();
        let sites = spec.build_sites();
        let graph = spec.graph();
        let x = cross_site_allreduce(&sites, &graph, 4, 1e9);
        assert!(x.intra_s > 0.0 && x.wan_s > 0.0);
        assert!((x.total - (x.intra_s + x.wan_s)).abs() < 1e-12);
        assert!(x.wan_util > 0.0 && x.wan_util <= 1.0 + 1e-9);
        // 2(S-1) steps of S flows each, on top of the intra flows.
        assert!(x.flows > 6 * 4);
    }
}
