//! Progressive-filling flow simulator over a `Fabric`.
//!
//! Perf notes (docs/bench.md): the rate allocator and utilisation tracker
//! use dense per-link vectors with mark/reset lists instead of hash maps,
//! all water-filling scratch lives on `FlowSim` (zero per-event heap
//! allocation), and rate recomputation is *incremental*: each admission or
//! retirement dirties only the links on that flow's path, and the solver
//! re-solves only the link-sharing connected components that contain dirty
//! links. Clean components keep their cached rates, which are bitwise
//! identical to a fresh solve because both the incremental and the
//! retained from-scratch reference mode ([`FlowSim::reference`]) run the
//! same per-component kernel over ascending slot order — the equivalence
//! is pinned by the property test in `tests/proptest_network.rs` and the
//! speedup is tracked by the committed `sakuraone bench` trajectory.

use std::collections::HashMap;

use super::roce::RoceParams;
use crate::topology::graph::{DeviceId, Fabric, LinkId};
use crate::topology::routing::Router;

/// Admission tolerance, relative to the current simulation time: flows
/// whose start is within `t * ADMIT_REL_EPS` of `t` join the current
/// event. The old absolute `1e-15` vanished against multi-day campaign
/// timestamps (t ~ 1e6 s).
const ADMIT_REL_EPS: f64 = 1e-12;

/// Bottleneck-freeze tolerance, relative to the bottleneck share. The old
/// absolute `1e-9` was meaningless at 800 GbE shares (~1e10 B/s).
const FREEZE_REL_EPS: f64 = 1e-9;

/// Retirement tolerance, relative to the flow's size. The old absolute
/// `1e-9` bytes forced extra micro-events on petabyte-scale flows.
const RETIRE_REL_EPS: f64 = 1e-12;

#[derive(Debug, Clone)]
pub struct Flow {
    pub src: DeviceId,
    pub dst: DeviceId,
    pub bytes: f64,
    pub start: f64,
    /// Flow label for ECMP hashing (e.g. QP number).
    pub label: u64,
}

#[derive(Debug, Clone)]
pub struct FlowResult {
    /// Time the last byte is delivered (includes path+transport latency).
    pub finish: f64,
    /// One-way path latency experienced by the flow.
    pub latency: f64,
    /// Average achieved throughput while active (bytes/s).
    pub avg_rate: f64,
    pub hops: usize,
}

#[derive(Debug, Clone, Default)]
pub struct SimReport {
    pub results: Vec<FlowResult>,
    /// Completion time of the whole batch.
    pub makespan: f64,
    /// Peak utilisation (0..1) per link id, sparse.
    pub peak_link_util: HashMap<LinkId, f64>,
    /// Total water-filling freeze rounds across all solved components — a
    /// deterministic, machine-independent work counter (the `sakuraone
    /// bench` manifest gates regressions on it, docs/bench.md). Depends on
    /// the solver mode: the incremental solver does strictly less work
    /// than [`FlowSim::reference`] on the same batch.
    pub rounds: usize,
}

impl SimReport {
    pub fn max_util(&self) -> f64 {
        self.peak_link_util.values().cloned().fold(0.0, f64::max)
    }
}

pub struct FlowSim<'f> {
    pub fabric: &'f Fabric,
    pub roce: RoceParams,
    router: Router<'f>,
    // dense per-link scratch, reused across runs (indexed by LinkId)
    residual: Vec<f64>,
    flows_on_link: Vec<u32>,
    peak_util: Vec<f64>,
    link_mark: Vec<bool>,
    /// Alive active-flow slots currently crossing each link.
    members: Vec<Vec<u32>>,
    dirty_mark: Vec<bool>,
    dirty_links: Vec<LinkId>,
    // per-slot scratch for component discovery
    in_comp: Vec<bool>,
    visited: Vec<u32>,
    comp_slots: Vec<u32>,
    comp_links: Vec<LinkId>,
    // water-filling scratch, hoisted out of the per-event hot path
    frozen: Vec<bool>,
    rates: Vec<f64>,
    order: Vec<u32>,
    reference_mode: bool,
}

struct ActiveFlow {
    idx: usize,
    /// Interned path id in the router (no per-flow `Vec<LinkId>` clone).
    path: u32,
    remaining: f64,
    rate: f64,
    started_at: f64,
    alive: bool,
}

impl<'f> FlowSim<'f> {
    pub fn new(fabric: &'f Fabric, roce: RoceParams) -> Self {
        let n = fabric.links.len();
        Self {
            fabric,
            roce,
            router: Router::new(fabric),
            residual: vec![0.0; n],
            flows_on_link: vec![0; n],
            peak_util: vec![0.0; n],
            link_mark: vec![false; n],
            members: vec![Vec::new(); n],
            dirty_mark: vec![false; n],
            dirty_links: Vec::new(),
            in_comp: Vec::new(),
            visited: Vec::new(),
            comp_slots: Vec::new(),
            comp_links: Vec::new(),
            frozen: Vec::new(),
            rates: Vec::new(),
            order: Vec::new(),
            reference_mode: false,
        }
    }

    /// The retained from-scratch reference solver: every event re-solves
    /// every component. Bitwise equivalent to the default incremental
    /// mode (proven by `tests/proptest_network.rs`) and kept both as the
    /// equivalence oracle and as the `_reference` bench cases' baseline.
    pub fn reference(fabric: &'f Fabric, roce: RoceParams) -> Self {
        let mut s = Self::new(fabric, roce);
        s.reference_mode = true;
        s
    }

    /// Simulate a batch of flows to completion. Panics if any flow is
    /// unroutable (callers must only schedule feasible transfers).
    /// The simulator is reusable: route caches persist across `run` calls.
    pub fn run(&mut self, flows: &[Flow]) -> SimReport {
        let mut report = SimReport {
            results: vec![
                FlowResult { finish: 0.0, latency: 0.0, avg_rate: 0.0, hops: 0 };
                flows.len()
            ],
            ..Default::default()
        };
        if flows.is_empty() {
            return report;
        }
        for u in self.peak_util.iter_mut() {
            *u = 0.0;
        }
        // drop dirt left behind by the previous run's final retirements
        for &l in &self.dirty_links {
            self.dirty_mark[l] = false;
        }
        self.dirty_links.clear();

        // Route everything up front (interned path ids, no clones).
        let mut pending: Vec<(usize, u32)> = Vec::new();
        for (i, fl) in flows.iter().enumerate() {
            if fl.src == fl.dst || fl.bytes <= 0.0 {
                report.results[i] = FlowResult {
                    finish: fl.start,
                    latency: 0.0,
                    avg_rate: f64::INFINITY,
                    hops: 0,
                };
                continue;
            }
            let pid = self
                .router
                .route_id(fl.src, fl.dst, fl.label)
                .unwrap_or_else(|| {
                    panic!("no route {} -> {}", fl.src, fl.dst)
                });
            pending.push((i, pid));
        }
        pending.sort_by(|a, b| {
            flows[a.0].start.partial_cmp(&flows[b.0].start).unwrap()
        });

        // Stable slot storage: retirement never moves another flow's slot,
        // so link membership lists and component discovery stay coherent.
        let mut slots: Vec<ActiveFlow> = Vec::new();
        let mut live: Vec<u32> = Vec::new();
        let mut t = 0.0f64;
        let mut next_pending = 0usize;
        let eff = self.roce.dcqcn_efficiency;

        while next_pending < pending.len() || !live.is_empty() {
            // admit flows that have started
            if live.is_empty() && next_pending < pending.len() {
                t = t.max(flows[pending[next_pending].0].start);
            }
            while next_pending < pending.len() {
                let (idx, pid) = pending[next_pending];
                let start = flows[idx].start;
                if start > t + t.abs() * ADMIT_REL_EPS {
                    break;
                }
                let slot = slots.len() as u32;
                slots.push(ActiveFlow {
                    idx,
                    path: pid,
                    remaining: flows[idx].bytes,
                    rate: 0.0,
                    started_at: start,
                    alive: true,
                });
                live.push(slot);
                for &l in self.router.path(pid) {
                    self.members[l].push(slot);
                    if !self.dirty_mark[l] {
                        self.dirty_mark[l] = true;
                        self.dirty_links.push(l);
                    }
                }
                next_pending += 1;
            }

            // max-min fair rates (water-filling) + peak-utilisation update
            if self.reference_mode {
                self.solve_all(&mut slots, eff, &mut report.rounds);
            } else {
                self.solve_dirty(&mut slots, eff, &mut report.rounds);
            }

            // next event: earliest completion or next admission
            let mut dt = f64::INFINITY;
            for &s in &live {
                let a = &slots[s as usize];
                if a.rate > 0.0 {
                    dt = dt.min(a.remaining / a.rate);
                }
            }
            if next_pending < pending.len() {
                dt = dt.min(flows[pending[next_pending].0].start - t);
            }
            assert!(
                dt.is_finite() && dt >= 0.0,
                "simulator stuck at t={t} with {} active flows",
                live.len()
            );
            t += dt;

            // progress + retire
            let mut i = 0;
            while i < live.len() {
                let s = live[i] as usize;
                slots[s].remaining -= slots[s].rate * dt;
                let bytes = flows[slots[s].idx].bytes;
                if slots[s].remaining <= bytes * RETIRE_REL_EPS {
                    let pid = slots[s].path;
                    let path = self.router.path(pid);
                    let hops = path.len();
                    let path_lat = self.fabric.path_latency(path)
                        + self.roce.transport_latency;
                    let duration = (t - slots[s].started_at).max(1e-12);
                    report.results[slots[s].idx] = FlowResult {
                        finish: t + path_lat,
                        latency: path_lat,
                        avg_rate: bytes / duration,
                        hops,
                    };
                    slots[s].alive = false;
                    for &l in self.router.path(pid) {
                        let m = &mut self.members[l];
                        if let Some(pos) =
                            m.iter().position(|&x| x == s as u32)
                        {
                            m.swap_remove(pos);
                        }
                        if !self.dirty_mark[l] {
                            self.dirty_mark[l] = true;
                            self.dirty_links.push(l);
                        }
                    }
                    live.swap_remove(i);
                } else {
                    i += 1;
                }
            }
        }

        report.makespan = report
            .results
            .iter()
            .map(|r| r.finish)
            .fold(0.0, f64::max);
        report.peak_link_util = self
            .peak_util
            .iter()
            .enumerate()
            .filter(|(_, &u)| u > 0.0)
            .map(|(l, &u)| (l, u))
            .collect();
        report
    }

    /// Incremental re-solve: only the link-sharing components that contain
    /// a dirty link (touched by an admitted/retired flow since the last
    /// solve) are re-gathered and re-solved; every other component keeps
    /// its cached rates, bitwise identical to a fresh solve.
    fn solve_dirty(
        &mut self,
        slots: &mut [ActiveFlow],
        eff: f64,
        rounds: &mut usize,
    ) {
        if slots.len() > self.in_comp.len() {
            self.in_comp.resize(slots.len(), false);
        }
        let mut seeds = std::mem::take(&mut self.dirty_links);
        for &l in &seeds {
            self.dirty_mark[l] = false;
        }
        for si in 0..seeds.len() {
            let l = seeds[si];
            let mut mi = 0;
            while mi < self.members[l].len() {
                let m = self.members[l][mi];
                mi += 1;
                if !self.in_comp[m as usize] {
                    self.gather_component(slots, m);
                    self.solve_component(slots, eff, rounds);
                }
            }
        }
        for k in 0..self.visited.len() {
            self.in_comp[self.visited[k] as usize] = false;
        }
        self.visited.clear();
        seeds.clear();
        self.dirty_links = seeds; // hand the buffer back, no realloc
    }

    /// Reference mode: re-gather and re-solve every component from scratch
    /// on every event (ascending slot order, same kernel as the
    /// incremental path — this is what makes the two modes bitwise equal).
    fn solve_all(
        &mut self,
        slots: &mut [ActiveFlow],
        eff: f64,
        rounds: &mut usize,
    ) {
        if slots.len() > self.in_comp.len() {
            self.in_comp.resize(slots.len(), false);
        }
        for &l in &self.dirty_links {
            self.dirty_mark[l] = false;
        }
        self.dirty_links.clear();
        for s in 0..slots.len() {
            if !slots[s].alive || self.in_comp[s] {
                continue;
            }
            self.gather_component(slots, s as u32);
            self.solve_component(slots, eff, rounds);
        }
        for k in 0..self.visited.len() {
            self.in_comp[self.visited[k] as usize] = false;
        }
        self.visited.clear();
    }

    /// BFS over link-sharing flows from `seed_slot` into `comp_slots`,
    /// sorted ascending so the solve order (and therefore every FP result)
    /// is independent of discovery order.
    fn gather_component(&mut self, slots: &[ActiveFlow], seed_slot: u32) {
        self.comp_slots.clear();
        self.in_comp[seed_slot as usize] = true;
        self.comp_slots.push(seed_slot);
        let mut qi = 0;
        while qi < self.comp_slots.len() {
            let s = self.comp_slots[qi] as usize;
            qi += 1;
            let pid = slots[s].path;
            for &l in self.router.path(pid) {
                for mi in 0..self.members[l].len() {
                    let m = self.members[l][mi];
                    if !self.in_comp[m as usize] {
                        self.in_comp[m as usize] = true;
                        self.comp_slots.push(m);
                    }
                }
            }
        }
        self.comp_slots.sort_unstable();
        self.visited.extend_from_slice(&self.comp_slots);
    }

    /// Water-filling max-min fair allocation within one component, with
    /// the optional per-flow DCQCN cap. All scratch is `FlowSim` state —
    /// zero allocation per call.
    fn solve_component(
        &mut self,
        slots: &mut [ActiveFlow],
        eff: f64,
        rounds: &mut usize,
    ) {
        let n = self.comp_slots.len();
        self.comp_links.clear();
        for ci in 0..n {
            let pid = slots[self.comp_slots[ci] as usize].path;
            for &l in self.router.path(pid) {
                if !self.link_mark[l] {
                    self.link_mark[l] = true;
                    self.comp_links.push(l);
                    self.residual[l] = self.fabric.links[l].bandwidth * eff;
                    self.flows_on_link[l] = 0;
                }
                self.flows_on_link[l] += 1;
            }
        }
        self.frozen.clear();
        self.frozen.resize(n, false);
        self.rates.clear();
        self.rates.resize(n, 0.0);
        self.order.clear();
        self.order.extend(0..n as u32);
        let cap = if self.roce.per_flow_cap > 0.0 {
            self.roce.per_flow_cap
        } else {
            f64::INFINITY
        };
        while !self.order.is_empty() {
            *rounds += 1;
            // bottleneck link: min fair share among links w/ unfrozen flows
            let mut best = f64::INFINITY;
            for &l in &self.comp_links {
                let cnt = self.flows_on_link[l];
                if cnt == 0 {
                    continue;
                }
                let share = self.residual[l] / cnt as f64;
                if share < best {
                    best = share;
                }
            }
            if !best.is_finite() {
                break;
            }
            let share = best.min(cap);
            let cap_binds = cap.is_finite() && cap <= best;
            // relative freeze bound; `best + |best|*eps` is >= best for
            // any sign, so the argmin link always freezes and the loop
            // always progresses
            let limit = best + best.abs() * FREEZE_REL_EPS;
            let mut froze_any = false;
            let mut w = 0;
            for r in 0..self.order.len() {
                let ci = self.order[r] as usize;
                let pid = slots[self.comp_slots[ci] as usize].path;
                let on_bottleneck = cap_binds
                    || self.router.path(pid).iter().any(|&l| {
                        let cnt = self.flows_on_link[l];
                        cnt > 0
                            && (self.residual[l] / cnt as f64).min(cap)
                                <= limit
                    });
                if on_bottleneck {
                    self.frozen[ci] = true;
                    self.rates[ci] = share;
                    froze_any = true;
                    for &l in self.router.path(pid) {
                        self.residual[l] -= share;
                        self.flows_on_link[l] -= 1;
                    }
                } else {
                    self.order[w] = self.order[r];
                    w += 1;
                }
            }
            self.order.truncate(w);
            if !froze_any {
                break;
            }
        }
        for ci in 0..n {
            slots[self.comp_slots[ci] as usize].rate = self.rates[ci];
        }
        for k in 0..self.comp_links.len() {
            let l = self.comp_links[k];
            self.link_mark[l] = false;
            // residual now = capacity - sum(rates on l)
            let capacity = self.fabric.links[l].bandwidth * eff;
            let util =
                ((capacity - self.residual[l]) / capacity).clamp(0.0, 1.0);
            if util > self.peak_util[l] {
                self.peak_util[l] = util;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;
    use crate::topology::builders::rail_optimized;
    use crate::util::units::ethernet_payload_bps;

    fn sim_cfg() -> ClusterConfig {
        ClusterConfig::default()
    }

    fn host_bw(cfg: &ClusterConfig) -> f64 {
        ethernet_payload_bps(
            cfg.network.node_leaf_gbps,
            cfg.network.ethernet_efficiency,
        )
    }

    #[test]
    fn single_flow_runs_at_line_rate() {
        let cfg = sim_cfg();
        let f = rail_optimized(&cfg);
        let mut sim = FlowSim::new(&f, RoceParams::default());
        let a = f.host(0, 0).unwrap();
        let b = f.host(1, 0).unwrap();
        let gb = 1e9;
        let rep = sim.run(&[Flow { src: a, dst: b, bytes: gb, start: 0.0, label: 0 }]);
        let expect = gb / (host_bw(&cfg) * sim.roce.dcqcn_efficiency);
        let got = rep.results[0].finish;
        assert!(
            (got - expect).abs() / expect < 0.01,
            "got {got}, expect ~{expect}"
        );
    }

    #[test]
    fn two_flows_into_one_nic_halve() {
        let cfg = sim_cfg();
        let f = rail_optimized(&cfg);
        let mut sim = FlowSim::new(&f, RoceParams::default());
        let a = f.host(0, 0).unwrap();
        let b = f.host(1, 0).unwrap();
        let c = f.host(2, 0).unwrap();
        let gb = 1e9;
        let rep = sim.run(&[
            Flow { src: a, dst: c, bytes: gb, start: 0.0, label: 0 },
            Flow { src: b, dst: c, bytes: gb, start: 0.0, label: 1 },
        ]);
        let one = gb / (host_bw(&cfg) * sim.roce.dcqcn_efficiency);
        assert!((rep.makespan - 2.0 * one).abs() / (2.0 * one) < 0.02);
    }

    #[test]
    fn early_finisher_releases_bandwidth() {
        let cfg = sim_cfg();
        let f = rail_optimized(&cfg);
        let mut sim = FlowSim::new(&f, RoceParams::default());
        let a = f.host(0, 0).unwrap();
        let b = f.host(1, 0).unwrap();
        let c = f.host(2, 0).unwrap();
        let gb = 1e9;
        let rep = sim.run(&[
            Flow { src: a, dst: c, bytes: gb, start: 0.0, label: 0 },
            Flow { src: b, dst: c, bytes: gb / 10.0, start: 0.0, label: 1 },
        ]);
        let one = gb / (host_bw(&cfg) * sim.roce.dcqcn_efficiency);
        assert!((rep.makespan - 1.1 * one).abs() / one < 0.05);
        assert!(rep.results[1].finish < rep.results[0].finish);
    }

    #[test]
    fn staggered_starts_respected() {
        let cfg = sim_cfg();
        let f = rail_optimized(&cfg);
        let mut sim = FlowSim::new(&f, RoceParams::default());
        let a = f.host(0, 0).unwrap();
        let b = f.host(1, 0).unwrap();
        let rep = sim.run(&[Flow {
            src: a,
            dst: b,
            bytes: 1e6,
            start: 5.0,
            label: 0,
        }]);
        assert!(rep.results[0].finish > 5.0);
    }

    #[test]
    fn rail_local_latency_is_two_hops() {
        let cfg = sim_cfg();
        let f = rail_optimized(&cfg);
        let mut sim = FlowSim::new(&f, RoceParams::default());
        let a = f.host(0, 4).unwrap();
        let b = f.host(3, 4).unwrap();
        let rep = sim.run(&[Flow { src: a, dst: b, bytes: 1.0, start: 0.0, label: 0 }]);
        assert_eq!(rep.results[0].hops, 2);
        assert!(rep.results[0].latency < 10e-6);
    }

    #[test]
    fn cross_pod_uses_four_hops() {
        let cfg = sim_cfg();
        let f = rail_optimized(&cfg);
        let mut sim = FlowSim::new(&f, RoceParams::default());
        let a = f.host(0, 0).unwrap();
        let b = f.host(75, 0).unwrap();
        let rep = sim.run(&[Flow { src: a, dst: b, bytes: 1.0, start: 0.0, label: 0 }]);
        assert_eq!(rep.results[0].hops, 4);
    }

    #[test]
    fn per_flow_cap_binds() {
        let cfg = sim_cfg();
        let f = rail_optimized(&cfg);
        let roce = RoceParams { per_flow_cap: 1e9, ..RoceParams::default() };
        let mut sim = FlowSim::new(&f, roce);
        let a = f.host(0, 0).unwrap();
        let b = f.host(1, 0).unwrap();
        let rep = sim.run(&[Flow { src: a, dst: b, bytes: 1e9, start: 0.0, label: 0 }]);
        assert!((rep.results[0].finish - 1.0).abs() < 0.01, "{}", rep.results[0].finish);
    }

    #[test]
    fn zero_byte_flow_finishes_instantly() {
        let cfg = sim_cfg();
        let f = rail_optimized(&cfg);
        let mut sim = FlowSim::new(&f, RoceParams::default());
        let a = f.host(0, 0).unwrap();
        let b = f.host(1, 0).unwrap();
        let rep = sim.run(&[Flow { src: a, dst: b, bytes: 0.0, start: 3.0, label: 0 }]);
        assert_eq!(rep.results[0].finish, 3.0);
    }

    #[test]
    fn utilisation_bounded_by_one() {
        let cfg = sim_cfg();
        let f = rail_optimized(&cfg);
        let mut sim = FlowSim::new(&f, RoceParams::default());
        let flows: Vec<Flow> = (0..8)
            .map(|n| Flow {
                src: f.host(n, 0).unwrap(),
                dst: f.host(9, 0).unwrap(),
                bytes: 1e8,
                start: 0.0,
                label: n as u64,
            })
            .collect();
        let rep = sim.run(&flows);
        assert!(rep.max_util() <= 1.0 + 1e-9);
        assert!(rep.max_util() > 0.99); // destination link saturated
    }

    #[test]
    fn conservation_of_bytes() {
        let cfg = sim_cfg();
        let f = rail_optimized(&cfg);
        let mut sim = FlowSim::new(&f, RoceParams::default());
        let n_src = 5;
        let bytes = 2e8;
        let flows: Vec<Flow> = (0..n_src)
            .map(|n| Flow {
                src: f.host(n, 2).unwrap(),
                dst: f.host(20, 2).unwrap(),
                bytes,
                start: 0.0,
                label: n as u64,
            })
            .collect();
        let rep = sim.run(&flows);
        let bottleneck = host_bw(&cfg) * sim.roce.dcqcn_efficiency;
        let lower = n_src as f64 * bytes / bottleneck;
        assert!(rep.makespan >= lower * 0.999, "{} < {}", rep.makespan, lower);
        assert!(rep.makespan <= lower * 1.05);
    }

    #[test]
    fn simulator_is_reusable_across_runs() {
        // route caches persist; results must be identical run-to-run
        let cfg = sim_cfg();
        let f = rail_optimized(&cfg);
        let mut sim = FlowSim::new(&f, RoceParams::default());
        let flows: Vec<Flow> = (0..16)
            .map(|n| Flow {
                src: f.host(n, 1).unwrap(),
                dst: f.host((n + 7) % 16, 1).unwrap(),
                bytes: 1e7,
                start: 0.0,
                label: n as u64,
            })
            .collect();
        let a = sim.run(&flows).makespan;
        let b = sim.run(&flows).makespan;
        assert_eq!(a, b);
    }

    #[test]
    fn reference_mode_agrees_on_a_small_batch() {
        // the full equivalence property lives in tests/proptest_network.rs;
        // this is the unit-sized smoke of the same contract
        let cfg = sim_cfg();
        let f = rail_optimized(&cfg);
        let flows: Vec<Flow> = (0..12)
            .map(|n| Flow {
                src: f.host(n, 1).unwrap(),
                dst: f.host((n * 5 + 3) % 20, 1).unwrap(),
                bytes: 1e7 + n as f64 * 3e6,
                start: n as f64 * 1e-4,
                label: n as u64,
            })
            .collect();
        let inc = FlowSim::new(&f, RoceParams::default()).run(&flows);
        let refr = FlowSim::reference(&f, RoceParams::default()).run(&flows);
        assert_eq!(inc.makespan.to_bits(), refr.makespan.to_bits());
        for (a, b) in inc.results.iter().zip(refr.results.iter()) {
            assert_eq!(a.finish.to_bits(), b.finish.to_bits());
            assert_eq!(a.avg_rate.to_bits(), b.avg_rate.to_bits());
        }
    }
}
