//! Progressive-filling flow simulator over a `Fabric`.
//!
//! Perf note (EXPERIMENTS.md §Perf): the rate allocator and utilisation
//! tracker use dense per-link vectors with a touched-list reset instead of
//! hash maps — the allocator runs every flow event and dominated the
//! simulator profile before this change.

use std::collections::HashMap;

use super::roce::RoceParams;
use crate::topology::graph::{DeviceId, Fabric, LinkId};
use crate::topology::routing::Router;

#[derive(Debug, Clone)]
pub struct Flow {
    pub src: DeviceId,
    pub dst: DeviceId,
    pub bytes: f64,
    pub start: f64,
    /// Flow label for ECMP hashing (e.g. QP number).
    pub label: u64,
}

#[derive(Debug, Clone)]
pub struct FlowResult {
    /// Time the last byte is delivered (includes path+transport latency).
    pub finish: f64,
    /// One-way path latency experienced by the flow.
    pub latency: f64,
    /// Average achieved throughput while active (bytes/s).
    pub avg_rate: f64,
    pub hops: usize,
}

#[derive(Debug, Clone, Default)]
pub struct SimReport {
    pub results: Vec<FlowResult>,
    /// Completion time of the whole batch.
    pub makespan: f64,
    /// Peak utilisation (0..1) per link id, sparse.
    pub peak_link_util: HashMap<LinkId, f64>,
    /// Number of rate recomputation rounds (perf counter).
    pub rounds: usize,
}

impl SimReport {
    pub fn max_util(&self) -> f64 {
        self.peak_link_util.values().cloned().fold(0.0, f64::max)
    }
}

pub struct FlowSim<'f> {
    pub fabric: &'f Fabric,
    pub roce: RoceParams,
    router: Router<'f>,
    // dense scratch, reused across runs (indexed by LinkId)
    residual: Vec<f64>,
    flows_on_link: Vec<u32>,
    peak_util: Vec<f64>,
    touched: Vec<LinkId>,
}

struct ActiveFlow {
    idx: usize,
    path: Vec<LinkId>,
    remaining: f64,
    rate: f64,
    started_at: f64,
}

impl<'f> FlowSim<'f> {
    pub fn new(fabric: &'f Fabric, roce: RoceParams) -> Self {
        let n = fabric.links.len();
        Self {
            fabric,
            roce,
            router: Router::new(fabric),
            residual: vec![0.0; n],
            flows_on_link: vec![0; n],
            peak_util: vec![0.0; n],
            touched: Vec::new(),
        }
    }

    /// Simulate a batch of flows to completion. Panics if any flow is
    /// unroutable (callers must only schedule feasible transfers).
    /// The simulator is reusable: route caches persist across `run` calls.
    pub fn run(&mut self, flows: &[Flow]) -> SimReport {
        let mut report = SimReport {
            results: vec![
                FlowResult { finish: 0.0, latency: 0.0, avg_rate: 0.0, hops: 0 };
                flows.len()
            ],
            ..Default::default()
        };
        if flows.is_empty() {
            return report;
        }
        for u in self.peak_util.iter_mut() {
            *u = 0.0;
        }

        // Route everything up front.
        let mut pending: Vec<(usize, &Flow, Vec<LinkId>)> = Vec::new();
        for (i, fl) in flows.iter().enumerate() {
            if fl.src == fl.dst || fl.bytes <= 0.0 {
                report.results[i] = FlowResult {
                    finish: fl.start,
                    latency: 0.0,
                    avg_rate: f64::INFINITY,
                    hops: 0,
                };
                continue;
            }
            let path = self
                .router
                .route(fl.src, fl.dst, fl.label)
                .unwrap_or_else(|| {
                    panic!("no route {} -> {}", fl.src, fl.dst)
                });
            pending.push((i, fl, path));
        }
        pending.sort_by(|a, b| a.1.start.partial_cmp(&b.1.start).unwrap());

        let mut active: Vec<ActiveFlow> = Vec::new();
        let mut t = 0.0f64;
        let mut next_pending = 0usize;
        let eff = self.roce.dcqcn_efficiency;

        while next_pending < pending.len() || !active.is_empty() {
            // admit flows that have started
            if active.is_empty() && next_pending < pending.len() {
                t = t.max(pending[next_pending].1.start);
            }
            while next_pending < pending.len()
                && pending[next_pending].1.start <= t + 1e-15
            {
                let (idx, fl, path) = &pending[next_pending];
                active.push(ActiveFlow {
                    idx: *idx,
                    path: path.clone(),
                    remaining: fl.bytes,
                    rate: 0.0,
                    started_at: fl.start,
                });
                next_pending += 1;
            }

            // max-min fair rates (water-filling) + peak-utilisation update
            self.assign_rates(&mut active, eff);
            report.rounds += 1;

            // next event: earliest completion or next admission
            let mut dt = f64::INFINITY;
            for a in &active {
                if a.rate > 0.0 {
                    dt = dt.min(a.remaining / a.rate);
                }
            }
            if next_pending < pending.len() {
                dt = dt.min(pending[next_pending].1.start - t);
            }
            assert!(
                dt.is_finite() && dt >= 0.0,
                "simulator stuck at t={t} with {} active flows",
                active.len()
            );
            t += dt;

            // progress + retire
            let mut i = 0;
            while i < active.len() {
                active[i].remaining -= active[i].rate * dt;
                if active[i].remaining <= 1e-9 {
                    let a = active.swap_remove(i);
                    let fl = flows[a.idx].clone();
                    let path_lat = self.fabric.path_latency(&a.path)
                        + self.roce.transport_latency;
                    let duration = (t - a.started_at).max(1e-12);
                    report.results[a.idx] = FlowResult {
                        finish: t + path_lat,
                        latency: path_lat,
                        avg_rate: fl.bytes / duration,
                        hops: a.path.len(),
                    };
                } else {
                    i += 1;
                }
            }
        }

        report.makespan = report
            .results
            .iter()
            .map(|r| r.finish)
            .fold(0.0, f64::max);
        report.peak_link_util = self
            .peak_util
            .iter()
            .enumerate()
            .filter(|(_, &u)| u > 0.0)
            .map(|(l, &u)| (l, u))
            .collect();
        report
    }

    /// Water-filling max-min fair allocation among active flows, with the
    /// optional per-flow DCQCN cap. Dense per-link scratch; O(rounds *
    /// touched-links) instead of hashing.
    fn assign_rates(&mut self, active: &mut [ActiveFlow], eff: f64) {
        let n = active.len();
        if n == 0 {
            return;
        }
        // reset scratch for the touched set only
        for &l in &self.touched {
            self.residual[l] = 0.0;
            self.flows_on_link[l] = 0;
        }
        self.touched.clear();
        for a in active.iter() {
            for &l in &a.path {
                if self.flows_on_link[l] == 0 && self.residual[l] == 0.0 {
                    self.residual[l] = self.fabric.links[l].bandwidth * eff;
                    self.touched.push(l);
                }
                self.flows_on_link[l] += 1;
            }
        }
        let mut frozen = vec![false; n];
        let mut rates = vec![0.0f64; n];
        let cap = if self.roce.per_flow_cap > 0.0 {
            self.roce.per_flow_cap
        } else {
            f64::INFINITY
        };
        loop {
            // bottleneck link: min fair share among links with unfrozen flows
            let mut best_share = f64::INFINITY;
            for &l in &self.touched {
                let cnt = self.flows_on_link[l];
                if cnt == 0 {
                    continue;
                }
                let share = self.residual[l] / cnt as f64;
                if share < best_share {
                    best_share = share;
                }
            }
            if !best_share.is_finite() {
                break;
            }
            let share = best_share.min(cap);
            let cap_binds = share >= cap - 1e-9 && cap.is_finite();
            let mut froze_any = false;
            for (i, a) in active.iter().enumerate() {
                if frozen[i] {
                    continue;
                }
                let on_bottleneck = cap_binds
                    || a.path.iter().any(|&l| {
                        let cnt = self.flows_on_link[l];
                        cnt > 0
                            && (self.residual[l] / cnt as f64).min(cap)
                                <= share + 1e-9
                    });
                if on_bottleneck {
                    frozen[i] = true;
                    rates[i] = share;
                    froze_any = true;
                    for &l in &a.path {
                        self.residual[l] -= share;
                        self.flows_on_link[l] -= 1;
                    }
                }
            }
            if !froze_any || frozen.iter().all(|&f| f) {
                break;
            }
        }
        // peak utilisation: re-derive link loads from final rates
        for (i, a) in active.iter_mut().enumerate() {
            a.rate = rates[i];
        }
        for &l in &self.touched {
            // residual now = capacity - sum(rates on l)
            let capacity = self.fabric.links[l].bandwidth * eff;
            let util = ((capacity - self.residual[l]) / capacity).clamp(0.0, 1.0);
            if util > self.peak_util[l] {
                self.peak_util[l] = util;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;
    use crate::topology::builders::rail_optimized;
    use crate::util::units::ethernet_payload_bps;

    fn sim_cfg() -> ClusterConfig {
        ClusterConfig::default()
    }

    fn host_bw(cfg: &ClusterConfig) -> f64 {
        ethernet_payload_bps(
            cfg.network.node_leaf_gbps,
            cfg.network.ethernet_efficiency,
        )
    }

    #[test]
    fn single_flow_runs_at_line_rate() {
        let cfg = sim_cfg();
        let f = rail_optimized(&cfg);
        let mut sim = FlowSim::new(&f, RoceParams::default());
        let a = f.host(0, 0).unwrap();
        let b = f.host(1, 0).unwrap();
        let gb = 1e9;
        let rep = sim.run(&[Flow { src: a, dst: b, bytes: gb, start: 0.0, label: 0 }]);
        let expect = gb / (host_bw(&cfg) * sim.roce.dcqcn_efficiency);
        let got = rep.results[0].finish;
        assert!(
            (got - expect).abs() / expect < 0.01,
            "got {got}, expect ~{expect}"
        );
    }

    #[test]
    fn two_flows_into_one_nic_halve() {
        let cfg = sim_cfg();
        let f = rail_optimized(&cfg);
        let mut sim = FlowSim::new(&f, RoceParams::default());
        let a = f.host(0, 0).unwrap();
        let b = f.host(1, 0).unwrap();
        let c = f.host(2, 0).unwrap();
        let gb = 1e9;
        let rep = sim.run(&[
            Flow { src: a, dst: c, bytes: gb, start: 0.0, label: 0 },
            Flow { src: b, dst: c, bytes: gb, start: 0.0, label: 1 },
        ]);
        let one = gb / (host_bw(&cfg) * sim.roce.dcqcn_efficiency);
        assert!((rep.makespan - 2.0 * one).abs() / (2.0 * one) < 0.02);
    }

    #[test]
    fn early_finisher_releases_bandwidth() {
        let cfg = sim_cfg();
        let f = rail_optimized(&cfg);
        let mut sim = FlowSim::new(&f, RoceParams::default());
        let a = f.host(0, 0).unwrap();
        let b = f.host(1, 0).unwrap();
        let c = f.host(2, 0).unwrap();
        let gb = 1e9;
        let rep = sim.run(&[
            Flow { src: a, dst: c, bytes: gb, start: 0.0, label: 0 },
            Flow { src: b, dst: c, bytes: gb / 10.0, start: 0.0, label: 1 },
        ]);
        let one = gb / (host_bw(&cfg) * sim.roce.dcqcn_efficiency);
        assert!((rep.makespan - 1.1 * one).abs() / one < 0.05);
        assert!(rep.results[1].finish < rep.results[0].finish);
    }

    #[test]
    fn staggered_starts_respected() {
        let cfg = sim_cfg();
        let f = rail_optimized(&cfg);
        let mut sim = FlowSim::new(&f, RoceParams::default());
        let a = f.host(0, 0).unwrap();
        let b = f.host(1, 0).unwrap();
        let rep = sim.run(&[Flow {
            src: a,
            dst: b,
            bytes: 1e6,
            start: 5.0,
            label: 0,
        }]);
        assert!(rep.results[0].finish > 5.0);
    }

    #[test]
    fn rail_local_latency_is_two_hops() {
        let cfg = sim_cfg();
        let f = rail_optimized(&cfg);
        let mut sim = FlowSim::new(&f, RoceParams::default());
        let a = f.host(0, 4).unwrap();
        let b = f.host(3, 4).unwrap();
        let rep = sim.run(&[Flow { src: a, dst: b, bytes: 1.0, start: 0.0, label: 0 }]);
        assert_eq!(rep.results[0].hops, 2);
        assert!(rep.results[0].latency < 10e-6);
    }

    #[test]
    fn cross_pod_uses_four_hops() {
        let cfg = sim_cfg();
        let f = rail_optimized(&cfg);
        let mut sim = FlowSim::new(&f, RoceParams::default());
        let a = f.host(0, 0).unwrap();
        let b = f.host(75, 0).unwrap();
        let rep = sim.run(&[Flow { src: a, dst: b, bytes: 1.0, start: 0.0, label: 0 }]);
        assert_eq!(rep.results[0].hops, 4);
    }

    #[test]
    fn per_flow_cap_binds() {
        let cfg = sim_cfg();
        let f = rail_optimized(&cfg);
        let roce = RoceParams { per_flow_cap: 1e9, ..RoceParams::default() };
        let mut sim = FlowSim::new(&f, roce);
        let a = f.host(0, 0).unwrap();
        let b = f.host(1, 0).unwrap();
        let rep = sim.run(&[Flow { src: a, dst: b, bytes: 1e9, start: 0.0, label: 0 }]);
        assert!((rep.results[0].finish - 1.0).abs() < 0.01, "{}", rep.results[0].finish);
    }

    #[test]
    fn zero_byte_flow_finishes_instantly() {
        let cfg = sim_cfg();
        let f = rail_optimized(&cfg);
        let mut sim = FlowSim::new(&f, RoceParams::default());
        let a = f.host(0, 0).unwrap();
        let b = f.host(1, 0).unwrap();
        let rep = sim.run(&[Flow { src: a, dst: b, bytes: 0.0, start: 3.0, label: 0 }]);
        assert_eq!(rep.results[0].finish, 3.0);
    }

    #[test]
    fn utilisation_bounded_by_one() {
        let cfg = sim_cfg();
        let f = rail_optimized(&cfg);
        let mut sim = FlowSim::new(&f, RoceParams::default());
        let flows: Vec<Flow> = (0..8)
            .map(|n| Flow {
                src: f.host(n, 0).unwrap(),
                dst: f.host(9, 0).unwrap(),
                bytes: 1e8,
                start: 0.0,
                label: n as u64,
            })
            .collect();
        let rep = sim.run(&flows);
        assert!(rep.max_util() <= 1.0 + 1e-9);
        assert!(rep.max_util() > 0.99); // destination link saturated
    }

    #[test]
    fn conservation_of_bytes() {
        let cfg = sim_cfg();
        let f = rail_optimized(&cfg);
        let mut sim = FlowSim::new(&f, RoceParams::default());
        let n_src = 5;
        let bytes = 2e8;
        let flows: Vec<Flow> = (0..n_src)
            .map(|n| Flow {
                src: f.host(n, 2).unwrap(),
                dst: f.host(20, 2).unwrap(),
                bytes,
                start: 0.0,
                label: n as u64,
            })
            .collect();
        let rep = sim.run(&flows);
        let bottleneck = host_bw(&cfg) * sim.roce.dcqcn_efficiency;
        let lower = n_src as f64 * bytes / bottleneck;
        assert!(rep.makespan >= lower * 0.999, "{} < {}", rep.makespan, lower);
        assert!(rep.makespan <= lower * 1.05);
    }

    #[test]
    fn simulator_is_reusable_across_runs() {
        // route caches persist; results must be identical run-to-run
        let cfg = sim_cfg();
        let f = rail_optimized(&cfg);
        let mut sim = FlowSim::new(&f, RoceParams::default());
        let flows: Vec<Flow> = (0..16)
            .map(|n| Flow {
                src: f.host(n, 1).unwrap(),
                dst: f.host((n + 7) % 16, 1).unwrap(),
                bytes: 1e7,
                start: 0.0,
                label: n as u64,
            })
            .collect();
        let a = sim.run(&flows).makespan;
        let b = sim.run(&flows).makespan;
        assert_eq!(a, b);
    }
}
