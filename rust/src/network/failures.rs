//! Failure injection on the fabric — exercises the resilience claims of
//! the rail-optimized design (§2.2: "redundant paths, adaptive routing
//! ... fault tolerance").
//!
//! A `FailurePlan` removes switches or individual cables from a built
//! `Fabric`; routing and the flow simulator then operate on the degraded
//! graph, so collective slowdowns and reachability loss *emerge* rather
//! than being scripted.

use crate::topology::graph::{Device, Fabric, SwitchTier};

#[derive(Debug, Clone, Default, PartialEq)]
pub struct FailurePlan {
    /// Spine switches to fail (by ordinal among spines).
    pub spines: Vec<usize>,
    /// Leaf switches to fail (by ordinal among leaves).
    pub leaves: Vec<usize>,
    /// Fraction of leaf-spine cables to sever (deterministic by seed).
    pub cable_fraction: f64,
    pub seed: u64,
}

impl FailurePlan {
    pub fn spine_down(n: usize) -> Self {
        Self { spines: (0..n).collect(), ..Default::default() }
    }

    pub fn leaf_down(n: usize) -> Self {
        Self { leaves: (0..n).collect(), ..Default::default() }
    }

    /// Sever a deterministic `fraction` of the leaf↔spine cables.
    pub fn cable_cuts(fraction: f64, seed: u64) -> Self {
        Self { cable_fraction: fraction, seed, ..Default::default() }
    }
}

/// Apply a failure plan: returns a new fabric with the selected devices'
/// links removed (devices stay in the vector so ids remain stable).
pub fn apply(fabric: &Fabric, plan: &FailurePlan) -> Fabric {
    let mut dead = vec![false; fabric.devices.len()];
    let mut spine_i = 0;
    let mut leaf_i = 0;
    for (id, d) in fabric.devices.iter().enumerate() {
        if let Device::Switch { tier, .. } = d {
            match tier {
                SwitchTier::Spine => {
                    if plan.spines.contains(&spine_i) {
                        dead[id] = true;
                    }
                    spine_i += 1;
                }
                SwitchTier::Leaf => {
                    if plan.leaves.contains(&leaf_i) {
                        dead[id] = true;
                    }
                    leaf_i += 1;
                }
            }
        }
    }
    let mut rng = crate::util::rng::Rng::new(plan.seed);
    let mut out = Fabric::new();
    for d in &fabric.devices {
        out.add_device(d.clone());
    }
    for l in &fabric.links {
        if dead[l.from] || dead[l.to] {
            continue;
        }
        let switch_to_switch = matches!(
            fabric.devices[l.from],
            Device::Switch { .. }
        ) && matches!(fabric.devices[l.to], Device::Switch { .. });
        if switch_to_switch
            && plan.cable_fraction > 0.0
            && rng.uniform() < plan.cable_fraction
        {
            continue;
        }
        out.add_link(l.from, l.to, l.bandwidth, l.latency);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::CollectiveEngine;
    use crate::config::ClusterConfig;
    use crate::topology::builders::rail_optimized;

    fn setup() -> (ClusterConfig, Fabric) {
        let cfg = ClusterConfig::default();
        let f = rail_optimized(&cfg);
        (cfg, f)
    }

    #[test]
    fn one_spine_down_keeps_full_reachability() {
        let (_cfg, f) = setup();
        let degraded = apply(&f, &FailurePlan::spine_down(1));
        let a = degraded.host(0, 0).unwrap();
        let b = degraded.host(99, 0).unwrap();
        let paths = degraded.ecmp_paths(a, b, 64);
        assert_eq!(paths.len(), 7, "7 of 8 spines remain");
    }

    #[test]
    fn seven_spines_down_still_connected() {
        let (_cfg, f) = setup();
        let degraded = apply(&f, &FailurePlan::spine_down(7));
        let a = degraded.host(0, 3).unwrap();
        let b = degraded.host(99, 3).unwrap();
        assert_eq!(degraded.ecmp_paths(a, b, 64).len(), 1);
    }

    #[test]
    fn spine_failure_slows_cross_pod_collectives_gracefully() {
        let (cfg, f) = setup();
        let engine_ok = CollectiveEngine::new(&f, &cfg);
        let nodes: Vec<usize> = (0..cfg.nodes).collect();
        let t_ok = engine_ok.hierarchical_allreduce(&nodes, 1e9).total;

        let degraded = apply(&f, &FailurePlan::spine_down(4));
        let engine_deg = CollectiveEngine::new(&degraded, &cfg);
        let t_deg = engine_deg.hierarchical_allreduce(&nodes, 1e9).total;
        // half the spine capacity gone: slower, but far from 8x collapse
        assert!(t_deg >= t_ok, "{t_deg} < {t_ok}");
        assert!(t_deg < 4.0 * t_ok, "collapse: {t_deg} vs {t_ok}");
    }

    #[test]
    fn leaf_failure_cuts_its_rail_in_that_pod() {
        let (_cfg, f) = setup();
        // leaf ordinal 0 = pod 0 rail 0
        let degraded = apply(&f, &FailurePlan::leaf_down(1));
        let a = degraded.host(0, 0).unwrap(); // pod 0 rail 0 — orphaned
        let b = degraded.host(1, 0).unwrap();
        assert!(degraded.ecmp_paths(a, b, 8).is_empty());
        // other rails unaffected
        let c = degraded.host(0, 1).unwrap();
        let d = degraded.host(1, 1).unwrap();
        assert!(!degraded.ecmp_paths(c, d, 8).is_empty());
    }

    #[test]
    fn cable_cuts_reduce_ecmp_fanout() {
        let (_cfg, f) = setup();
        let plan = FailurePlan { cable_fraction: 0.3, seed: 5, ..Default::default() };
        let degraded = apply(&f, &plan);
        let a = degraded.host(0, 0).unwrap();
        let b = degraded.host(99, 0).unwrap();
        let before = f.ecmp_paths(a, b, 64).len();
        let after = degraded.ecmp_paths(a, b, 64).len();
        assert!(after < before, "{after} !< {before}");
        assert!(after > 0, "must stay connected at 30% cuts");
    }

    #[test]
    fn failure_is_deterministic_by_seed() {
        let (_cfg, f) = setup();
        let plan = FailurePlan { cable_fraction: 0.5, seed: 9, ..Default::default() };
        let a = apply(&f, &plan);
        let b = apply(&f, &plan);
        assert_eq!(a.links.len(), b.links.len());
    }
}
