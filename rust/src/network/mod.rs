//! Flow-level network simulator with RoCEv2 semantics.
//!
//! Model: flows are routed over ECMP shortest paths; while active they
//! share every traversed link max-min fairly (progressive filling), the
//! steady-state a converged DCQCN keeps a lossless PFC fabric in. The
//! simulator advances from flow event to flow event (start/finish),
//! recomputing the fair-share allocation — the standard flow-level
//! abstraction for Clos fabric studies.

pub mod failures;
pub mod roce;
pub mod sim;
pub mod wan;

pub use failures::{apply as apply_failures, FailurePlan};
pub use roce::RoceParams;
pub use sim::{Flow, FlowResult, FlowSim, SimReport};
pub use wan::{cross_site_allreduce, CrossSiteTime, HierReport, WanFlow, WanSim};
