//! RoCEv2 transport parameters (paper §2.2: RoCEv2 over lossless PFC
//! Ethernet with DCQCN congestion control).

#[derive(Debug, Clone)]
pub struct RoceParams {
    /// Fraction of the max-min fair share a converged DCQCN actually
    /// sustains (rate ramp + ECN marking headroom). Pichetti et al. 2024
    /// measure RoCEv2 within a few percent of InfiniBand on throughput.
    pub dcqcn_efficiency: f64,
    /// Per-QP static rate cap, bytes/s (0 = uncapped). Models the
    /// per-connection limit some deployments pin to tame incast.
    pub per_flow_cap: f64,
    /// Extra one-way latency RoCEv2 adds over cut-through Ethernet
    /// (QP doorbell, CNP round trips amortised).
    pub transport_latency: f64,
    /// PFC pause propagation — modelled as lossless (no retransmits), so
    /// this only gates the latency of congested epochs.
    pub pfc_pause_latency: f64,
}

impl Default for RoceParams {
    fn default() -> Self {
        Self {
            dcqcn_efficiency: 0.95,
            per_flow_cap: 0.0,
            transport_latency: 1.5e-6,
            pfc_pause_latency: 0.7e-6,
        }
    }
}

impl RoceParams {
    /// Ideal lossless transport (InfiniBand-like baseline for ablations).
    pub fn ideal() -> Self {
        Self {
            dcqcn_efficiency: 1.0,
            per_flow_cap: 0.0,
            transport_latency: 0.6e-6,
            pfc_pause_latency: 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_derated() {
        let p = RoceParams::default();
        assert!(p.dcqcn_efficiency < 1.0);
        assert!(p.transport_latency > 0.0);
    }

    #[test]
    fn ideal_is_full_rate() {
        assert_eq!(RoceParams::ideal().dcqcn_efficiency, 1.0);
    }
}
