//! Real end-to-end LLM training through all three layers:
//! Pallas fused attention (L1) -> JAX train step (L2, AOT to HLO) ->
//! Rust platform driving the PJRT CPU client (L3).
//!
//! Trains the tiny causal LM for a few hundred SGD steps on a synthetic
//! low-entropy Markov corpus and logs the loss curve; the loss MUST drop
//! well below the uniform baseline ln(256) ~ 5.55 — the proof that the
//! whole stack composes (EXPERIMENTS.md §E2E).
//!
//!     cargo run --release --example llm_train -- [steps]

use sakuraone::config::ClusterConfig;
use sakuraone::coordinator::Platform;
use sakuraone::llm::{step_time, train, LlmConfig};
use sakuraone::topology::builders::build;

fn main() -> anyhow::Result<()> {
    let steps: u32 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);

    let cfg = ClusterConfig::default();
    let mut platform = Platform::new(cfg.clone());
    let rt = platform.runtime()?;
    println!(
        "# tiny-LM: vocab 256, d=64, 2 layers, batch 8x64 tokens, SGD",
    );
    println!("# platform: PJRT [{}], artifact train_step", rt.platform());
    let rep = train(rt, steps, 0)?;

    println!("step,loss");
    for (i, l) in rep.losses.iter().enumerate() {
        if i % 10 == 0 || i + 1 == rep.losses.len() {
            println!("{i},{l:.4}");
        }
    }
    let uniform = (256f64).ln();
    println!(
        "# loss {:.3} -> {:.3} (uniform baseline {:.3}) over {} tokens, {:.1}s ({:.0} tok/s)",
        rep.initial_loss,
        rep.final_loss,
        uniform,
        rep.tokens_seen,
        rep.wall_seconds,
        rep.tokens_seen as f64 / rep.wall_seconds
    );
    assert!(
        rep.final_loss < rep.initial_loss,
        "training did not learn: {} -> {}",
        rep.initial_loss,
        rep.final_loss
    );
    if steps >= 200 {
        // with a few hundred steps the model must beat the uniform
        // baseline on the 2-bit-entropy corpus
        assert!(
            rep.final_loss < uniform - 0.2,
            "loss {} did not beat uniform {uniform}",
            rep.final_loss
        );
    }
    println!("# E2E TRAINING CHECK: PASSED");

    // For context: what the same workload costs at cluster scale on the
    // simulated fabric (the paper's motivating deployment).
    let fabric = build(&cfg);
    let st = step_time(&cfg, &fabric, &LlmConfig::llama70b_on_sakuraone());
    println!(
        "# cluster-scale model: 70B on 800 GPUs -> {:.2} s/step, MFU {:.1}%, {:.0} tok/s",
        st.total,
        st.mfu * 100.0,
        st.tokens_per_s
    );
    Ok(())
}
