//! Quickstart: build the SAKURAONE platform, look at the fabric, run one
//! benchmark, and execute a real kernel through the PJRT runtime.
//!
//!     cargo run --release --example quickstart

use sakuraone::benchmarks::hpl::HplParams;
use sakuraone::config::ClusterConfig;
use sakuraone::coordinator::Platform;
use sakuraone::runtime::Runtime;
use sakuraone::topology::render::render_system;

fn main() -> anyhow::Result<()> {
    // 1. The paper's cluster: 100 nodes x 8 H100, rail-optimized 800GbE.
    let cfg = ClusterConfig::default();
    println!("{}", render_system(&cfg));

    // 2. Simulate the Table 7 HPL run.
    let mut platform = Platform::new(cfg);
    let hpl = platform.hpl(&HplParams::paper());
    println!(
        "HPL: {:.2} PFLOP/s in {:.0} s ({:.1} TF per GPU)",
        hpl.rmax / 1e15,
        hpl.time_s,
        hpl.rmax_per_gpu / 1e12
    );

    // 3. Execute the real tiled-GEMM Pallas kernel through PJRT (L1->L3).
    match platform.runtime() {
        Ok(rt) => {
            let n = 256;
            let a: Vec<f32> = (0..n * n).map(|i| (i % 7) as f32 * 0.1).collect();
            let b: Vec<f32> = (0..n * n).map(|i| (i % 5) as f32 * 0.1).collect();
            let out = rt.execute(
                "gemm_f32_256",
                &[
                    Runtime::lit_f32(&a, &[n, n])?,
                    Runtime::lit_f32(&b, &[n, n])?,
                ],
            )?;
            let c = Runtime::to_vec_f32(&out[0])?;
            println!(
                "PJRT gemm_f32_256 on [{}]: c[0][0..4] = {:?}",
                rt.platform(),
                &c[..4]
            );
        }
        Err(e) => println!("(runtime unavailable — run `make artifacts`: {e})"),
    }

    // 4. Numerics validation, the paper's Table 9 PASS criterion.
    if let Ok(check) = platform.validate_hpl_numerics() {
        println!(
            "HPL numerics: scaled residual {:.2e} < {} => {}",
            check.scaled_residual,
            check.threshold,
            if check.passed() { "PASSED" } else { "FAILED" }
        );
    }
    Ok(())
}
