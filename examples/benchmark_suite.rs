//! End-to-end driver: regenerates every evaluation artifact of the paper
//! (Tables 3, 7, 8, 9, 10) on the simulated cluster, validates the
//! numerics through the AOT'd PJRT artifacts, and prints paper-vs-measured
//! comparisons. This is the run recorded in EXPERIMENTS.md.
//!
//!     cargo run --release --example benchmark_suite

use sakuraone::benchmarks::hpcg::HpcgParams;
use sakuraone::benchmarks::hpl::HplParams;
use sakuraone::benchmarks::hpl_mxp::MxpParams;
use sakuraone::benchmarks::io500::{comparison_table, Io500Params};
use sakuraone::benchmarks::{report, top500};
use sakuraone::config::ClusterConfig;
use sakuraone::coordinator::Platform;
use sakuraone::llm::train;
use sakuraone::topology::render::{render_network, render_system};

fn main() -> anyhow::Result<()> {
    let cfg = ClusterConfig::default();
    println!("{}", render_system(&cfg));
    println!(
        "{}",
        render_network(&cfg, &sakuraone::topology::build(&cfg))
    );
    let mut platform = Platform::new(cfg);

    // ---- T7 HPL ----------------------------------------------------------
    let hpl = platform.hpl(&HplParams::paper());
    println!("{}", hpl.table());
    println!("{}", report::hpl_compare(&hpl).render());

    // ---- T8 HPCG ---------------------------------------------------------
    let hpcg = platform.hpcg(&HpcgParams::paper());
    println!("{}", hpcg.table());
    println!("{}", report::hpcg_compare(&hpcg).render());

    // ---- T9 HPL-MxP ------------------------------------------------------
    let mxp = platform.mxp(&MxpParams::paper());
    println!("{}", mxp.table());
    println!("{}", report::mxp_compare(&mxp).render());

    // ---- T10 IO500 -------------------------------------------------------
    let r10 = platform.io500(&Io500Params::paper_10node());
    let r96 = platform.io500(&Io500Params::paper_96node());
    println!("{}", comparison_table(&r10, &r96).render());
    println!("{}", report::io500_compare(&r10, &r96).render());

    // ---- T3 + rankings ----------------------------------------------------
    println!("{}", top500::census_table().render());
    println!("{}", top500::rankings_table().render());

    // ---- headline shape checks (the reproduction criteria) ---------------
    let mxp_speedup = mxp.rmax / hpl.rmax;
    let hpcg_frac = hpcg.final_gflops * 1e9 / hpl.rmax;
    println!("shape checks:");
    println!(
        "  HPL-MxP / HPL speedup          : {mxp_speedup:.1}x   (paper: ~10x)"
    );
    println!(
        "  HPCG / HPL fraction            : {:.2}%  (paper: ~1%)",
        hpcg_frac * 100.0
    );
    println!(
        "  IO500 96n > 10n total          : {}     (paper: 214.09 > 181.91)",
        r96.total_score > r10.total_score
    );
    println!(
        "  IO500 96n < 10n easy-write BW  : {}     (paper: 198.80 < 262.91)",
        r96.phase("ior-easy-write").score < r10.phase("ior-easy-write").score
    );
    assert!(mxp_speedup > 8.0 && mxp_speedup < 12.0);
    assert!(hpcg_frac > 0.005 && hpcg_frac < 0.02);
    assert!(r96.total_score > r10.total_score);

    // ---- real numerics through the PJRT artifacts -------------------------
    match platform.validate_hpl_numerics() {
        Ok(c) => {
            println!(
                "HPL numerics    : scaled residual {:.2e} => {}",
                c.scaled_residual,
                if c.passed() { "PASSED" } else { "FAILED" }
            );
            assert!(c.passed());
            let m = platform.validate_mxp_numerics()?;
            println!(
                "HPL-MxP numerics: scaled residual {:.2e} => {}",
                m.scaled_residual,
                if m.passed() { "PASSED" } else { "FAILED" }
            );
            assert!(m.passed());
            let g = platform.validate_hpcg_numerics()?;
            println!(
                "HPCG numerics   : ||r||^2 {:.2e} -> {:.2e} => {}",
                g.rr0,
                g.rr_final,
                if g.passed() { "PASSED" } else { "FAILED" }
            );
            assert!(g.passed());

            // short real training run proving the full stack composes
            let rt = platform.runtime()?;
            let rep = train(rt, 30, 0)?;
            println!(
                "E2E train (30 steps): loss {:.3} -> {:.3} => {}",
                rep.initial_loss,
                rep.final_loss,
                if rep.final_loss < rep.initial_loss { "LEARNING" } else { "FLAT" }
            );
            assert!(rep.final_loss < rep.initial_loss);
        }
        Err(e) => println!("(PJRT validation skipped — run `make artifacts`: {e})"),
    }

    println!("\nmetrics: {}", platform.metrics.to_json().emit());
    println!("SUITE COMPLETE");
    Ok(())
}
