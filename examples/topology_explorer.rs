//! Topology ablation (DESIGN.md §5): compare the four fabrics the paper
//! surveys — rail-optimized (SAKURAONE's choice), rail-only, fat-tree and
//! dragonfly — on the metrics that drove the paper's design decision:
//! bisection bandwidth, hierarchical all-reduce time (the LLM gradient
//! pattern), HPL wall time, and cluster-scale LLM step time.
//!
//!     cargo run --release --example topology_explorer

use sakuraone::benchmarks::hpl::{run_hpl, HplParams};
use sakuraone::collectives::CollectiveEngine;
use sakuraone::config::{ClusterConfig, TopologyKind};
use sakuraone::llm::{step_time, LlmConfig};
use sakuraone::topology::builders::build;
use sakuraone::topology::pod_of;
use sakuraone::util::table::Table;

fn main() {
    let kinds = [
        TopologyKind::RailOptimized,
        TopologyKind::RailOnly,
        TopologyKind::FatTree,
        TopologyKind::Dragonfly,
    ];
    let mut t = Table::new(
        "Topology ablation — 100 nodes x 8 rails, identical link budgets",
        &[
            "topology",
            "bisection (Tb/s)",
            "hier-allreduce 1GiB (ms)",
            "HPL time (s)",
            "70B LLM step (s)",
            "LLM MFU",
        ],
    );
    for kind in kinds {
        let mut cfg = ClusterConfig::default();
        cfg.network.topology = kind;
        let fabric = build(&cfg);

        let bisect = fabric
            .bisection_bandwidth(|n| pod_of(&cfg, n) == 0)
            * 8.0
            / 1e12;

        let engine = CollectiveEngine::new(&fabric, &cfg);
        let nodes: Vec<usize> = (0..cfg.nodes).collect();
        let ar = engine.hierarchical_allreduce(&nodes, 1024.0 * 1024.0 * 1024.0);

        let hpl = run_hpl(&cfg, &HplParams::paper());

        let llm = LlmConfig { dp: 100, tp: 8, pp: 1, ..LlmConfig::llama70b_on_sakuraone() };
        let st = step_time(&cfg, &fabric, &llm);

        t.row(&[
            kind.name().to_string(),
            format!("{bisect:.1}"),
            format!("{:.1}", ar.total * 1e3),
            format!("{:.1}", hpl.time_s),
            format!("{:.2}", st.total),
            format!("{:.1}%", st.mfu * 100.0),
        ]);
    }
    println!("{}", t.render());
    println!(
        "note: rail-only has no Ethernet path between rails (cross-rail \
         traffic must hop through NVSwitch), which is why the paper's \
         rail-optimized design adds the spine layer."
    );
}
